//! Moment encoding: partition `M = XᵀX` into row blocks and encode each
//! with an `(N, K)` linear code (Scheme 1 / Scheme 2 with the k > K
//! generalization of footnote 2).
//!
//! * Rows of `M` are split into `⌈k/K⌉` blocks `M_{P_i}` of `K` rows
//!   (the last block zero-padded if `K ∤ k`).
//! * Each block is encoded columnwise: `C⁽ⁱ⁾ = G · M_{P_i} ∈ ℝ^{N x k}`.
//! * Worker `j` receives row `j` of every `C⁽ⁱ⁾`, stacked into one
//!   `(blocks x k)` shard so its whole per-step task is a single mat-vec
//!   `shard_j · θ` (α = k/K inner products, one scalar per block).
//!
//! At the master, the response vector of worker `j` holds coordinate `j`
//! of every block codeword `C⁽ⁱ⁾θ`; the per-step erasure pattern (the
//! straggler set) is therefore *identical across blocks*, which is what
//! lets the peeling schedule be computed once and replayed.

use crate::error::{Error, Result};
use crate::linalg::Matrix;

/// The result of block moment encoding.
#[derive(Debug, Clone)]
pub struct BlockMomentEncoding {
    /// Problem dimension `k` (columns of `M`).
    pub k: usize,
    /// Code length `N` (== number of workers).
    pub n: usize,
    /// Code dimension `K`.
    pub code_k: usize,
    /// Number of row blocks `⌈k/K⌉`.
    pub blocks: usize,
    /// Per-worker shards, each `(blocks x k)`.
    pub shards: Vec<Matrix>,
}

impl BlockMomentEncoding {
    /// Encode the moment matrix with a columnwise encoder
    /// `encode(M_msg: K x d) -> N x d`.
    ///
    /// All `⌈k/K⌉` row blocks are stacked side by side into one
    /// `K x (blocks·k)` message matrix and encoded with a *single*
    /// call — one large GEMM that the packed register-tiled kernel
    /// spreads across the persistent linalg pool (the schemes thread a
    /// reusable `GemmScratch` pack buffer through this closure) —
    /// instead of `blocks` small sequential ones. A columnwise encoder
    /// treats every column independently,
    /// so the coded values are bit-identical to per-block encoding.
    /// Tradeoff: the stacked message and the full coded matrix are
    /// transiently live alongside the shards, roughly doubling the
    /// one-time encode's peak memory versus per-block encoding.
    pub fn new<F>(moment: &Matrix, n: usize, code_k: usize, mut encode: F) -> Result<Self>
    where
        F: FnMut(&Matrix) -> Result<Matrix>,
    {
        let k = moment.cols();
        if moment.rows() != k {
            return Err(Error::Config("moment matrix must be square".into()));
        }
        if code_k == 0 {
            return Err(Error::Config("code dimension must be positive".into()));
        }
        let blocks = k.div_ceil(code_k);
        let stacked_cols = blocks
            .checked_mul(k)
            .ok_or_else(|| Error::Config(format!("encoding shape {blocks}x{k} overflows")))?;
        // Column range i*k..(i+1)*k holds block i: its K message rows
        // are rows lo..hi of M, zero-padded below when K ∤ k.
        let mut stacked = Matrix::try_zeros(code_k, stacked_cols)
            .map_err(|e| Error::Config(format!("moment encoding too large: {e}")))?;
        for i in 0..blocks {
            let lo = i * code_k;
            let hi = ((i + 1) * code_k).min(k);
            for (bi, r) in (lo..hi).enumerate() {
                stacked.row_mut(bi)[i * k..(i + 1) * k].copy_from_slice(moment.row(r));
            }
        }
        let coded = encode(&stacked)?;
        if coded.shape() != (n, stacked_cols) {
            return Err(Error::Config(format!(
                "encoder returned {:?}, expected ({n}, {stacked_cols})",
                coded.shape()
            )));
        }
        // Codeword position j's shard is row j of the coded matrix,
        // reinterpreted as `blocks x k` row-major — a straight memcpy.
        let mut shards = Vec::with_capacity(n);
        for j in 0..n {
            let mut shard = Matrix::try_zeros(blocks, k)?;
            shard.as_mut_slice().copy_from_slice(coded.row(j));
            shards.push(shard);
        }
        Ok(BlockMomentEncoding { k, n, code_k, blocks, shards })
    }

    /// Per-worker row count α = blocks = ⌈k/K⌉ (Table 1's `α = n/w` with
    /// `n = N·k/K` and `N = w`).
    pub fn alpha(&self) -> usize {
        self.blocks
    }

    /// Assemble the block-`i` codeword from per-worker responses
    /// (`responses[j][i]`), writing 0.0 at erased positions.
    pub fn block_codeword(
        &self,
        block: usize,
        responses: &[Option<Vec<f64>>],
        out: &mut Vec<f64>,
    ) {
        out.clear();
        out.reserve(self.n);
        for r in responses.iter() {
            out.push(match r {
                Some(v) => v[block],
                None => 0.0,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::ldpc::LdpcCode;
    use crate::rng::Rng;

    #[test]
    fn shards_reconstruct_coded_blocks() {
        let mut rng = Rng::new(1);
        let k = 40; // 2 blocks of K=20
        let m = Matrix::gaussian(k, k, &mut rng);
        let code = LdpcCode::gallager(40, 20, 3, 6, 2).unwrap();
        let enc =
            BlockMomentEncoding::new(&m, 40, 20, |blk| code.encode_matrix(blk)).unwrap();
        assert_eq!(enc.blocks, 2);
        assert_eq!(enc.alpha(), 2);
        assert_eq!(enc.shards.len(), 40);
        for shard in &enc.shards {
            assert_eq!(shard.shape(), (2, 40));
        }
        // Worker j, block i must hold row j of G * M_{P_i}.
        let block0 = m.select_rows(&(0..20).collect::<Vec<_>>());
        let coded0 = code.encode_matrix(&block0).unwrap();
        for j in 0..40 {
            assert_eq!(enc.shards[j].row(0), coded0.row(j));
        }
    }

    #[test]
    fn responses_form_codewords() {
        // The paper's key step-invariant: for any θ, the vector of worker
        // inner products for a block is a codeword of C.
        let mut rng = Rng::new(3);
        let k = 60;
        let m = Matrix::gaussian(k, k, &mut rng);
        let code = LdpcCode::gallager(40, 20, 3, 6, 4).unwrap();
        let enc =
            BlockMomentEncoding::new(&m, 40, 20, |blk| code.encode_matrix(blk)).unwrap();
        let theta = rng.gaussian_vec(k);
        let responses: Vec<Option<Vec<f64>>> =
            enc.shards.iter().map(|s| Some(s.matvec(&theta))).collect();
        let mut cw = Vec::new();
        for i in 0..enc.blocks {
            enc.block_codeword(i, &responses, &mut cw);
            assert!(code.is_codeword(&cw, 1e-7), "block {i}");
            // Systematic prefix must equal (M θ) on the block rows.
            let mtheta = m.matvec(&theta);
            let lo = i * 20;
            for p in 0..20.min(k - lo) {
                assert!((cw[p] - mtheta[lo + p]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn padding_when_k_not_divisible() {
        let mut rng = Rng::new(5);
        let k = 50; // K=20 -> 3 blocks, last padded with 10 zero rows
        let m = Matrix::gaussian(k, k, &mut rng);
        let code = LdpcCode::gallager(40, 20, 3, 6, 6).unwrap();
        let enc =
            BlockMomentEncoding::new(&m, 40, 20, |blk| code.encode_matrix(blk)).unwrap();
        assert_eq!(enc.blocks, 3);
        let theta = rng.gaussian_vec(k);
        let responses: Vec<Option<Vec<f64>>> =
            enc.shards.iter().map(|s| Some(s.matvec(&theta))).collect();
        let mut cw = Vec::new();
        enc.block_codeword(2, &responses, &mut cw);
        let mtheta = m.matvec(&theta);
        // First 10 message coords are real rows 40..50, rest are padding.
        for p in 0..10 {
            assert!((cw[p] - mtheta[40 + p]).abs() < 1e-8);
        }
        for p in 10..20 {
            assert!(cw[p].abs() < 1e-9, "padded row should produce 0");
        }
    }

    #[test]
    fn erased_positions_zero_filled() {
        let mut rng = Rng::new(7);
        let m = Matrix::gaussian(20, 20, &mut rng);
        let code = LdpcCode::gallager(40, 20, 3, 6, 8).unwrap();
        let enc =
            BlockMomentEncoding::new(&m, 40, 20, |blk| code.encode_matrix(blk)).unwrap();
        let theta = rng.gaussian_vec(20);
        let mut responses: Vec<Option<Vec<f64>>> =
            enc.shards.iter().map(|s| Some(s.matvec(&theta))).collect();
        responses[3] = None;
        responses[17] = None;
        let mut cw = Vec::new();
        enc.block_codeword(0, &responses, &mut cw);
        assert_eq!(cw[3], 0.0);
        assert_eq!(cw[17], 0.0);
    }

    #[test]
    fn bad_encoder_shape_rejected() {
        let m = Matrix::zeros(10, 10);
        let r = BlockMomentEncoding::new(&m, 8, 5, |_| Ok(Matrix::zeros(7, 10)));
        assert!(r.is_err());
    }
}
