//! Straggler injection and worker-latency models.
//!
//! Two families live here:
//!
//! * [`StragglerModel`] decides *who* straggles each step — the paper's
//!   experiments fix the number of stragglers per step (s ∈ {5, 10} of 40
//!   workers — "we wait for either 30 or 35 workers"), the convergence
//!   analysis (Assumption 1) uses i.i.d. Bernoulli straggling, and a
//!   shifted-exponential order-statistics model supports wait-for-k runs.
//! * [`LatencyModel`] decides *when* each worker's response arrives —
//!   the virtual-time simulator (`crate::sim`) samples per-worker
//!   completion times from it and lets a deadline policy decide who is
//!   dropped. Beyond the canonical shifted exponential it covers
//!   heavy-tailed Pareto latencies, Markov-correlated slowdowns (a slow
//!   worker *stays* slow across steps), heterogeneous per-worker speeds,
//!   and replay of a recorded latency trace.

use std::sync::Arc;

use crate::rng::Rng;

/// Declarative straggler model (see [`StragglerSampler`] for the stateful
/// per-run sampler).
#[derive(Debug, Clone)]
pub enum StragglerModel {
    /// No stragglers.
    None,
    /// Exactly `s` uniformly random stragglers per step (§4's setup).
    FixedCount { s: usize, seed: u64 },
    /// Each worker independently straggles with probability `q0`
    /// (Assumption 1; drives Theorem 1's `(1 − q_D)` factor).
    Bernoulli { q0: f64, seed: u64 },
    /// Worker completion times are `shift + Exp(rate)` (milliseconds);
    /// the master waits for the fastest `wait_for` workers, the rest are
    /// stragglers. Simulated time is returned alongside the set.
    ShiftedExp { shift_ms: f64, rate: f64, wait_for: usize, seed: u64 },
}

impl StragglerModel {
    /// Create the stateful sampler for a run.
    pub fn sampler(&self) -> StragglerSampler {
        StragglerSampler { model: self.clone(), rng: Rng::new(self.seed()), step: 0 }
    }

    fn seed(&self) -> u64 {
        match *self {
            StragglerModel::None => 0,
            StragglerModel::FixedCount { seed, .. }
            | StragglerModel::Bernoulli { seed, .. }
            | StragglerModel::ShiftedExp { seed, .. } => seed,
        }
    }

    /// Short name for reports.
    pub fn name(&self) -> String {
        match *self {
            StragglerModel::None => "none".into(),
            StragglerModel::FixedCount { s, .. } => format!("fixed({s})"),
            StragglerModel::Bernoulli { q0, .. } => format!("bernoulli({q0})"),
            StragglerModel::ShiftedExp { wait_for, .. } => format!("shifted-exp(wait {wait_for})"),
        }
    }
}

/// The per-step straggler draw.
#[derive(Debug, Clone)]
pub struct StepStraggling {
    /// Sorted straggler indices.
    pub stragglers: Vec<usize>,
    /// Simulated per-worker completion times in ms (latency models only).
    pub latencies_ms: Option<Vec<f64>>,
    /// Simulated time until the master can proceed (latency models only):
    /// the slowest non-straggler.
    pub collect_ms: Option<f64>,
}

/// Stateful sampler; one per run, advanced once per gradient step.
#[derive(Debug, Clone)]
pub struct StragglerSampler {
    model: StragglerModel,
    rng: Rng,
    step: usize,
}

impl StragglerSampler {
    /// Draw the straggler set for the next step over `w` workers.
    pub fn next_step(&mut self, w: usize) -> StepStraggling {
        self.step += 1;
        match self.model {
            StragglerModel::None => StepStraggling {
                stragglers: Vec::new(),
                latencies_ms: None,
                collect_ms: None,
            },
            StragglerModel::FixedCount { s, .. } => {
                let s = s.min(w);
                StepStraggling {
                    stragglers: self.rng.choose_k(w, s),
                    latencies_ms: None,
                    collect_ms: None,
                }
            }
            StragglerModel::Bernoulli { q0, .. } => {
                let stragglers: Vec<usize> =
                    (0..w).filter(|_| self.rng.bernoulli(q0)).collect();
                StepStraggling { stragglers, latencies_ms: None, collect_ms: None }
            }
            StragglerModel::ShiftedExp { shift_ms, rate, wait_for, .. } => {
                let lat: Vec<f64> =
                    (0..w).map(|_| self.rng.shifted_exponential(shift_ms, rate)).collect();
                let wait_for = wait_for.min(w).max(1);
                // Order statistics: the slowest w - wait_for are stragglers.
                let mut order: Vec<usize> = (0..w).collect();
                order.sort_by(|&a, &b| lat[a].partial_cmp(&lat[b]).unwrap());
                let mut stragglers: Vec<usize> = order[wait_for..].to_vec();
                stragglers.sort_unstable();
                let collect = lat[order[wait_for - 1]];
                StepStraggling {
                    stragglers,
                    latencies_ms: Some(lat),
                    collect_ms: Some(collect),
                }
            }
        }
    }
}

/// Pluggable per-worker completion-latency models for the virtual-time
/// simulator (see [`StragglerSampler`]'s sibling [`LatencySampler`] for
/// the stateful per-run form). All times are in milliseconds of
/// *simulated* time.
#[derive(Debug, Clone)]
pub enum LatencyModel {
    /// i.i.d. `shift + Exp(rate)` per worker per step — the canonical
    /// model of the coded-computation literature (Lee et al. 2018,
    /// Tandon et al. "Gradient Coding").
    ShiftedExp {
        /// Deterministic base time (ms).
        shift_ms: f64,
        /// Exponential tail rate (1/ms).
        rate: f64,
        /// RNG seed.
        seed: u64,
    },
    /// Heavy-tailed i.i.d. Pareto: `scale · U^{-1/shape}`, so
    /// `P[X > t] = (scale/t)^shape` — occasional *extreme* stragglers,
    /// the regime where deadline collection beats wait-for-all hardest.
    Pareto {
        /// Minimum (and typical) latency (ms).
        scale_ms: f64,
        /// Tail index `α`; smaller = heavier tail.
        shape: f64,
        /// RNG seed.
        seed: u64,
    },
    /// Markov-correlated slowdowns: each worker carries a fast/slow
    /// state across steps (a slow worker *stays* slow). A fast worker
    /// turns slow with probability `p_slow`, a slow one recovers with
    /// probability `p_fast`; states start at the stationary mix
    /// `p_slow/(p_slow + p_fast)`. Slow workers' shifted-exponential
    /// latency is multiplied by `slowdown`.
    Markov {
        /// Base deterministic time (ms).
        shift_ms: f64,
        /// Exponential tail rate (1/ms).
        rate: f64,
        /// Multiplier applied while slow.
        slowdown: f64,
        /// P(fast → slow) per step.
        p_slow: f64,
        /// P(slow → fast) per step.
        p_fast: f64,
        /// RNG seed.
        seed: u64,
    },
    /// Heterogeneous fleet: a per-worker speed multiplier drawn once
    /// (uniform in `[1, spread]`) scales a shifted-exponential base —
    /// persistently slower machines rather than per-step noise.
    Heterogeneous {
        /// Base deterministic time (ms).
        shift_ms: f64,
        /// Exponential tail rate (1/ms).
        rate: f64,
        /// Slowest/fastest machine ratio (≥ 1).
        spread: f64,
        /// RNG seed.
        seed: u64,
    },
    /// Replay a recorded trace: step `t` over `w` workers reads
    /// `table[t % table.len()][j % row.len()]`. Pair with
    /// [`record_trace`] for a round-trippable capture of any other
    /// model.
    Trace {
        /// Step-major latency table (ms); must be non-empty with
        /// non-empty rows.
        table: Arc<Vec<Vec<f64>>>,
    },
}

impl LatencyModel {
    /// Create the stateful per-run sampler.
    pub fn sampler(&self) -> LatencySampler {
        LatencySampler {
            model: self.clone(),
            rng: Rng::new(self.seed()),
            slow: Vec::new(),
            mult: Vec::new(),
            step: 0,
        }
    }

    fn seed(&self) -> u64 {
        match *self {
            LatencyModel::ShiftedExp { seed, .. }
            | LatencyModel::Pareto { seed, .. }
            | LatencyModel::Markov { seed, .. }
            | LatencyModel::Heterogeneous { seed, .. } => seed,
            LatencyModel::Trace { .. } => 0,
        }
    }

    /// The same model with a fresh seed (trace replay is untouched —
    /// it has no randomness to vary).
    pub fn reseed(&self, seed: u64) -> LatencyModel {
        let mut m = self.clone();
        match &mut m {
            LatencyModel::ShiftedExp { seed: s, .. }
            | LatencyModel::Pareto { seed: s, .. }
            | LatencyModel::Markov { seed: s, .. }
            | LatencyModel::Heterogeneous { seed: s, .. } => *s = seed,
            LatencyModel::Trace { .. } => {}
        }
        m
    }

    /// Short name for reports.
    pub fn name(&self) -> String {
        match *self {
            LatencyModel::ShiftedExp { shift_ms, rate, .. } => {
                format!("shifted-exp({shift_ms},{rate})")
            }
            LatencyModel::Pareto { scale_ms, shape, .. } => {
                format!("pareto({scale_ms},{shape})")
            }
            LatencyModel::Markov { slowdown, p_slow, p_fast, .. } => {
                format!("markov(x{slowdown},{p_slow}/{p_fast})")
            }
            LatencyModel::Heterogeneous { spread, .. } => format!("hetero(x{spread})"),
            LatencyModel::Trace { .. } => "trace".into(),
        }
    }
}

/// Stateful latency sampler; one per run, advanced once per step. Two
/// samplers created from the same model produce bit-identical latency
/// sequences.
#[derive(Debug, Clone)]
pub struct LatencySampler {
    model: LatencyModel,
    rng: Rng,
    /// Markov per-worker slow flags (grown on first use).
    slow: Vec<bool>,
    /// Heterogeneous per-worker multipliers (drawn on first use).
    mult: Vec<f64>,
    step: usize,
}

impl LatencySampler {
    /// Sample the next step's per-worker completion times into `out`
    /// (cleared and filled with `w` entries).
    pub fn sample_into(&mut self, w: usize, out: &mut Vec<f64>) {
        out.clear();
        out.reserve(w);
        match self.model.clone() {
            LatencyModel::ShiftedExp { shift_ms, rate, .. } => {
                for _ in 0..w {
                    out.push(self.rng.shifted_exponential(shift_ms, rate));
                }
            }
            LatencyModel::Pareto { scale_ms, shape, .. } => {
                for _ in 0..w {
                    out.push(self.rng.pareto(scale_ms, shape));
                }
            }
            LatencyModel::Markov { shift_ms, rate, slowdown, p_slow, p_fast, .. } => {
                // First use: start each worker at the stationary mix so
                // the slow fraction has no burn-in transient.
                let pi_slow = p_slow / (p_slow + p_fast);
                while self.slow.len() < w {
                    let s = self.rng.bernoulli(pi_slow);
                    self.slow.push(s);
                }
                let LatencySampler { rng, slow, .. } = self;
                for st in slow.iter_mut().take(w) {
                    let s = if *st { !rng.bernoulli(p_fast) } else { rng.bernoulli(p_slow) };
                    *st = s;
                    let base = rng.shifted_exponential(shift_ms, rate);
                    out.push(if s { base * slowdown } else { base });
                }
            }
            LatencyModel::Heterogeneous { shift_ms, rate, spread, .. } => {
                while self.mult.len() < w {
                    let m = self.rng.uniform_range(1.0, spread.max(1.0));
                    self.mult.push(m);
                }
                let LatencySampler { rng, mult, .. } = self;
                for m in mult.iter().take(w) {
                    out.push(m * rng.shifted_exponential(shift_ms, rate));
                }
            }
            LatencyModel::Trace { table } => {
                assert!(!table.is_empty(), "latency trace is empty");
                let row = &table[self.step % table.len()];
                assert!(!row.is_empty(), "latency trace row is empty");
                for j in 0..w {
                    out.push(row[j % row.len()]);
                }
            }
        }
        self.step += 1;
    }

    /// Allocating convenience wrapper over [`LatencySampler::sample_into`].
    pub fn sample(&mut self, w: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.sample_into(w, &mut out);
        out
    }
}

/// Record `steps` draws of a model over `w` workers into a table
/// replayable through [`LatencyModel::Trace`] — the round-trippable
/// capture used to re-run an interesting straggler scenario exactly.
pub fn record_trace(model: &LatencyModel, w: usize, steps: usize) -> Vec<Vec<f64>> {
    let mut sampler = model.sampler();
    let mut out = Vec::with_capacity(steps);
    let mut buf = Vec::new();
    for _ in 0..steps {
        sampler.sample_into(w, &mut buf);
        out.push(buf.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_straggles() {
        let mut s = StragglerModel::None.sampler();
        for _ in 0..10 {
            assert!(s.next_step(40).stragglers.is_empty());
        }
    }

    #[test]
    fn fixed_count_exact() {
        let mut s = StragglerModel::FixedCount { s: 5, seed: 1 }.sampler();
        for _ in 0..100 {
            let st = s.next_step(40);
            assert_eq!(st.stragglers.len(), 5);
            assert!(st.stragglers.iter().all(|&i| i < 40));
            assert!(st.stragglers.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn fixed_count_varies_across_steps() {
        let mut s = StragglerModel::FixedCount { s: 5, seed: 2 }.sampler();
        let a = s.next_step(40).stragglers;
        let b = s.next_step(40).stragglers;
        assert_ne!(a, b, "straggler sets should differ step to step (w.h.p.)");
    }

    #[test]
    fn fixed_count_clamps_to_w() {
        let mut s = StragglerModel::FixedCount { s: 100, seed: 3 }.sampler();
        assert_eq!(s.next_step(10).stragglers.len(), 10);
    }

    #[test]
    fn bernoulli_rate_about_q0() {
        let mut s = StragglerModel::Bernoulli { q0: 0.25, seed: 4 }.sampler();
        let mut total = 0usize;
        let steps = 2000;
        for _ in 0..steps {
            total += s.next_step(40).stragglers.len();
        }
        let rate = total as f64 / (steps * 40) as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn shifted_exp_wait_for_semantics() {
        let mut s = StragglerModel::ShiftedExp {
            shift_ms: 10.0,
            rate: 0.1,
            wait_for: 30,
            seed: 5,
        }
        .sampler();
        for _ in 0..50 {
            let st = s.next_step(40);
            assert_eq!(st.stragglers.len(), 10);
            let lat = st.latencies_ms.unwrap();
            let collect = st.collect_ms.unwrap();
            assert!(collect >= 10.0, "shift respected");
            // Every straggler is slower than the collect time.
            for &w in &st.stragglers {
                assert!(lat[w] >= collect);
            }
            // Exactly `wait_for` workers at or below collect time.
            let fast = lat.iter().filter(|&&l| l <= collect).count();
            assert_eq!(fast, 30);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = StragglerModel::FixedCount { s: 7, seed: 9 }.sampler();
        let mut b = StragglerModel::FixedCount { s: 7, seed: 9 }.sampler();
        for _ in 0..20 {
            assert_eq!(a.next_step(40).stragglers, b.next_step(40).stragglers);
        }
    }

    #[test]
    fn recreated_samplers_replay_all_models() {
        // Seed determinism across *re-created* samplers, for every
        // straggler model kind: sampling must depend only on (model,
        // seed, step), never on sampler identity.
        let models = [
            StragglerModel::FixedCount { s: 5, seed: 11 },
            StragglerModel::Bernoulli { q0: 0.3, seed: 12 },
            StragglerModel::ShiftedExp { shift_ms: 5.0, rate: 0.2, wait_for: 25, seed: 13 },
        ];
        for model in &models {
            let mut a = model.sampler();
            let first: Vec<Vec<usize>> = (0..10).map(|_| a.next_step(40).stragglers).collect();
            let mut b = model.sampler();
            let second: Vec<Vec<usize>> = (0..10).map(|_| b.next_step(40).stragglers).collect();
            assert_eq!(first, second, "{}", model.name());
        }
    }

    #[test]
    fn shifted_exp_marks_workers_minus_wait_for() {
        for (w, wait_for) in [(40usize, 30usize), (64, 48), (10, 1), (10, 10)] {
            let mut s = StragglerModel::ShiftedExp {
                shift_ms: 2.0,
                rate: 0.5,
                wait_for,
                seed: 21,
            }
            .sampler();
            for _ in 0..20 {
                let st = s.next_step(w);
                assert_eq!(st.stragglers.len(), w - wait_for, "w={w} wait_for={wait_for}");
            }
        }
    }

    #[test]
    fn fixed_count_draws_exactly_s_distinct_indices() {
        let mut s = StragglerModel::FixedCount { s: 9, seed: 31 }.sampler();
        for _ in 0..200 {
            let st = s.next_step(64);
            assert_eq!(st.stragglers.len(), 9);
            // Sorted and strictly increasing => all distinct and in range.
            assert!(st.stragglers.windows(2).all(|w| w[0] < w[1]));
            assert!(st.stragglers.iter().all(|&i| i < 64));
        }
    }
}

#[cfg(test)]
mod latency_tests {
    use super::*;

    #[test]
    fn recreated_latency_samplers_bit_identical() {
        let models = [
            LatencyModel::ShiftedExp { shift_ms: 1.0, rate: 0.5, seed: 1 },
            LatencyModel::Pareto { scale_ms: 1.0, shape: 2.0, seed: 2 },
            LatencyModel::Markov {
                shift_ms: 1.0,
                rate: 1.0,
                slowdown: 10.0,
                p_slow: 0.1,
                p_fast: 0.3,
                seed: 3,
            },
            LatencyModel::Heterogeneous { shift_ms: 1.0, rate: 1.0, spread: 3.0, seed: 4 },
        ];
        for model in &models {
            let mut a = model.sampler();
            let mut b = model.sampler();
            for _ in 0..25 {
                assert_eq!(a.sample(16), b.sample(16), "{}", model.name());
            }
        }
    }

    #[test]
    fn reseed_changes_draws_but_not_shape() {
        let m = LatencyModel::ShiftedExp { shift_ms: 2.0, rate: 0.5, seed: 5 };
        let a = m.sampler().sample(32);
        let b = m.reseed(6).sampler().sample(32);
        assert_ne!(a, b);
        assert!(b.iter().all(|&l| l >= 2.0), "shift preserved after reseed");
    }

    #[test]
    fn pareto_tail_shape() {
        // P[X > 2·scale] = 2^-shape; with shape 2 that is 0.25, and the
        // support never dips below the scale.
        let m = LatencyModel::Pareto { scale_ms: 3.0, shape: 2.0, seed: 7 };
        let mut s = m.sampler();
        let mut over = 0usize;
        let mut total = 0usize;
        for _ in 0..500 {
            for &l in &s.sample(64) {
                assert!(l >= 3.0);
                total += 1;
                if l > 6.0 {
                    over += 1;
                }
            }
        }
        let frac = over as f64 / total as f64;
        assert!((frac - 0.25).abs() < 0.02, "tail fraction {frac}");
    }

    #[test]
    fn markov_stationary_slow_fraction() {
        // p_slow/(p_slow+p_fast) = 0.25 of workers slow on average, and
        // the ×100 slowdown makes the slow set readable off the latency
        // (the Exp(1) tail never reaches 100·shift).
        let m = LatencyModel::Markov {
            shift_ms: 1.0,
            rate: 1.0,
            slowdown: 100.0,
            p_slow: 0.1,
            p_fast: 0.3,
            seed: 8,
        };
        let mut s = m.sampler();
        let (w, steps) = (40, 2000);
        let mut slow_total = 0usize;
        for _ in 0..steps {
            slow_total += s.sample(w).iter().filter(|&&l| l > 50.0).count();
        }
        let frac = slow_total as f64 / (w * steps) as f64;
        assert!((frac - 0.25).abs() < 0.03, "stationary slow fraction {frac}");
    }

    #[test]
    fn markov_slow_workers_stay_slow() {
        // With a tiny recovery probability, a worker slow at step t is
        // almost always slow at step t+1 — the correlation that i.i.d.
        // models cannot express.
        let m = LatencyModel::Markov {
            shift_ms: 1.0,
            rate: 1.0,
            slowdown: 100.0,
            p_slow: 0.05,
            p_fast: 0.05,
            seed: 9,
        };
        let mut s = m.sampler();
        let w = 64;
        let mut prev: Vec<bool> = s.sample(w).iter().map(|&l| l > 50.0).collect();
        let mut stayed = 0usize;
        let mut was_slow = 0usize;
        for _ in 0..500 {
            let cur: Vec<bool> = s.sample(w).iter().map(|&l| l > 50.0).collect();
            for j in 0..w {
                if prev[j] {
                    was_slow += 1;
                    if cur[j] {
                        stayed += 1;
                    }
                }
            }
            prev = cur;
        }
        assert!(was_slow > 0);
        let persistence = stayed as f64 / was_slow as f64;
        assert!(persistence > 0.85, "slow-state persistence {persistence}");
    }

    #[test]
    fn heterogeneous_multipliers_persist_per_worker() {
        // Per-worker minima over many steps expose the fixed multiplier:
        // with spread 3 the slowest machine's floor is well above the
        // fastest machine's.
        let m = LatencyModel::Heterogeneous { shift_ms: 10.0, rate: 10.0, spread: 3.0, seed: 10 };
        let mut s = m.sampler();
        let w = 16;
        let mut mins = vec![f64::INFINITY; w];
        for _ in 0..300 {
            for (j, &l) in s.sample(w).iter().enumerate() {
                mins[j] = mins[j].min(l);
            }
        }
        let lo = mins.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = mins.iter().cloned().fold(0.0f64, f64::max);
        assert!(lo >= 10.0, "floor below shift: {lo}");
        assert!(hi / lo > 1.2, "multiplier spread invisible: {lo}..{hi}");
    }

    #[test]
    fn trace_replay_round_trip() {
        // record_trace(model) replayed through LatencyModel::Trace must
        // reproduce the original model's draws bit-for-bit, then wrap.
        let base = LatencyModel::ShiftedExp { shift_ms: 1.0, rate: 0.7, seed: 11 };
        let (w, steps) = (8, 5);
        let table = record_trace(&base, w, steps);
        assert_eq!(table.len(), steps);

        let mut orig = base.sampler();
        let mut replay = LatencyModel::Trace { table: Arc::new(table.clone()) }.sampler();
        for _ in 0..steps {
            assert_eq!(orig.sample(w), replay.sample(w));
        }
        // Past the end the trace wraps to step 0.
        assert_eq!(replay.sample(w), table[0]);
    }

    #[test]
    fn trace_tiles_rows_over_more_workers() {
        let table = vec![vec![1.0, 2.0]];
        let mut s = LatencyModel::Trace { table: Arc::new(table) }.sampler();
        assert_eq!(s.sample(5), vec![1.0, 2.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn model_names_are_stable() {
        assert!(LatencyModel::ShiftedExp { shift_ms: 1.0, rate: 0.5, seed: 0 }
            .name()
            .starts_with("shifted-exp"));
        assert!(LatencyModel::Pareto { scale_ms: 1.0, shape: 2.0, seed: 0 }
            .name()
            .starts_with("pareto"));
        assert_eq!(
            LatencyModel::Trace { table: Arc::new(vec![vec![1.0]]) }.name(),
            "trace"
        );
    }
}
