//! Straggler injection models.
//!
//! The paper's experiments fix the number of stragglers per step (s ∈ {5,
//! 10} of 40 workers — "we wait for either 30 or 35 workers"), while the
//! convergence analysis (Assumption 1) uses i.i.d. Bernoulli straggling.
//! The shifted-exponential latency model from the coded-computation
//! literature is also provided for deadline-driven experiments.

use crate::rng::Rng;

/// Declarative straggler model (see [`StragglerSampler`] for the stateful
/// per-run sampler).
#[derive(Debug, Clone)]
pub enum StragglerModel {
    /// No stragglers.
    None,
    /// Exactly `s` uniformly random stragglers per step (§4's setup).
    FixedCount { s: usize, seed: u64 },
    /// Each worker independently straggles with probability `q0`
    /// (Assumption 1; drives Theorem 1's `(1 − q_D)` factor).
    Bernoulli { q0: f64, seed: u64 },
    /// Worker completion times are `shift + Exp(rate)` (milliseconds);
    /// the master waits for the fastest `wait_for` workers, the rest are
    /// stragglers. Simulated time is returned alongside the set.
    ShiftedExp { shift_ms: f64, rate: f64, wait_for: usize, seed: u64 },
}

impl StragglerModel {
    /// Create the stateful sampler for a run.
    pub fn sampler(&self) -> StragglerSampler {
        StragglerSampler { model: self.clone(), rng: Rng::new(self.seed()), step: 0 }
    }

    fn seed(&self) -> u64 {
        match *self {
            StragglerModel::None => 0,
            StragglerModel::FixedCount { seed, .. }
            | StragglerModel::Bernoulli { seed, .. }
            | StragglerModel::ShiftedExp { seed, .. } => seed,
        }
    }

    /// Short name for reports.
    pub fn name(&self) -> String {
        match *self {
            StragglerModel::None => "none".into(),
            StragglerModel::FixedCount { s, .. } => format!("fixed({s})"),
            StragglerModel::Bernoulli { q0, .. } => format!("bernoulli({q0})"),
            StragglerModel::ShiftedExp { wait_for, .. } => format!("shifted-exp(wait {wait_for})"),
        }
    }
}

/// The per-step straggler draw.
#[derive(Debug, Clone)]
pub struct StepStraggling {
    /// Sorted straggler indices.
    pub stragglers: Vec<usize>,
    /// Simulated per-worker completion times in ms (latency models only).
    pub latencies_ms: Option<Vec<f64>>,
    /// Simulated time until the master can proceed (latency models only):
    /// the slowest non-straggler.
    pub collect_ms: Option<f64>,
}

/// Stateful sampler; one per run, advanced once per gradient step.
#[derive(Debug, Clone)]
pub struct StragglerSampler {
    model: StragglerModel,
    rng: Rng,
    step: usize,
}

impl StragglerSampler {
    /// Draw the straggler set for the next step over `w` workers.
    pub fn next_step(&mut self, w: usize) -> StepStraggling {
        self.step += 1;
        match self.model {
            StragglerModel::None => StepStraggling {
                stragglers: Vec::new(),
                latencies_ms: None,
                collect_ms: None,
            },
            StragglerModel::FixedCount { s, .. } => {
                let s = s.min(w);
                StepStraggling {
                    stragglers: self.rng.choose_k(w, s),
                    latencies_ms: None,
                    collect_ms: None,
                }
            }
            StragglerModel::Bernoulli { q0, .. } => {
                let stragglers: Vec<usize> =
                    (0..w).filter(|_| self.rng.bernoulli(q0)).collect();
                StepStraggling { stragglers, latencies_ms: None, collect_ms: None }
            }
            StragglerModel::ShiftedExp { shift_ms, rate, wait_for, .. } => {
                let lat: Vec<f64> =
                    (0..w).map(|_| self.rng.shifted_exponential(shift_ms, rate)).collect();
                let wait_for = wait_for.min(w).max(1);
                // Order statistics: the slowest w - wait_for are stragglers.
                let mut order: Vec<usize> = (0..w).collect();
                order.sort_by(|&a, &b| lat[a].partial_cmp(&lat[b]).unwrap());
                let mut stragglers: Vec<usize> = order[wait_for..].to_vec();
                stragglers.sort_unstable();
                let collect = lat[order[wait_for - 1]];
                StepStraggling {
                    stragglers,
                    latencies_ms: Some(lat),
                    collect_ms: Some(collect),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_straggles() {
        let mut s = StragglerModel::None.sampler();
        for _ in 0..10 {
            assert!(s.next_step(40).stragglers.is_empty());
        }
    }

    #[test]
    fn fixed_count_exact() {
        let mut s = StragglerModel::FixedCount { s: 5, seed: 1 }.sampler();
        for _ in 0..100 {
            let st = s.next_step(40);
            assert_eq!(st.stragglers.len(), 5);
            assert!(st.stragglers.iter().all(|&i| i < 40));
            assert!(st.stragglers.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn fixed_count_varies_across_steps() {
        let mut s = StragglerModel::FixedCount { s: 5, seed: 2 }.sampler();
        let a = s.next_step(40).stragglers;
        let b = s.next_step(40).stragglers;
        assert_ne!(a, b, "straggler sets should differ step to step (w.h.p.)");
    }

    #[test]
    fn fixed_count_clamps_to_w() {
        let mut s = StragglerModel::FixedCount { s: 100, seed: 3 }.sampler();
        assert_eq!(s.next_step(10).stragglers.len(), 10);
    }

    #[test]
    fn bernoulli_rate_about_q0() {
        let mut s = StragglerModel::Bernoulli { q0: 0.25, seed: 4 }.sampler();
        let mut total = 0usize;
        let steps = 2000;
        for _ in 0..steps {
            total += s.next_step(40).stragglers.len();
        }
        let rate = total as f64 / (steps * 40) as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn shifted_exp_wait_for_semantics() {
        let mut s = StragglerModel::ShiftedExp {
            shift_ms: 10.0,
            rate: 0.1,
            wait_for: 30,
            seed: 5,
        }
        .sampler();
        for _ in 0..50 {
            let st = s.next_step(40);
            assert_eq!(st.stragglers.len(), 10);
            let lat = st.latencies_ms.unwrap();
            let collect = st.collect_ms.unwrap();
            assert!(collect >= 10.0, "shift respected");
            // Every straggler is slower than the collect time.
            for &w in &st.stragglers {
                assert!(lat[w] >= collect);
            }
            // Exactly `wait_for` workers at or below collect time.
            let fast = lat.iter().filter(|&&l| l <= collect).count();
            assert_eq!(fast, 30);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = StragglerModel::FixedCount { s: 7, seed: 9 }.sampler();
        let mut b = StragglerModel::FixedCount { s: 7, seed: 9 }.sampler();
        for _ in 0..20 {
            assert_eq!(a.next_step(40).stragglers, b.next_step(40).stragglers);
        }
    }
}
