//! Dense real linear-algebra substrate.
//!
//! The coordinator, codes, and optimizers need matrices over `f64`:
//! Gram matrices, mat-vecs, Gaussian elimination (for systematic LDPC
//! generators and MDS erasure decoding), power iteration (for spectral
//! learning-rate selection), and a handful of vector helpers. This module
//! keeps everything row-major and allocation-explicit so the hot path can
//! reuse buffers.
//!
//! Compute layout: [`matrix`] owns shapes and entry points, [`gemm`]
//! owns the packed register-tiled kernels (and the retained scalar
//! reference every path is pinned against), and [`pool`] owns the
//! process-lifetime worker threads that band-parallel kernels dispatch
//! to. See the "Kernel design" section of `rust/README.md`.

pub mod gemm;
pub mod matrix;
pub mod ops;
pub mod pool;

pub use gemm::GemmScratch;
pub use matrix::Matrix;
pub use ops::*;
