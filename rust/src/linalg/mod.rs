//! Dense real linear-algebra substrate.
//!
//! The coordinator, codes, and optimizers need matrices over `f64`:
//! Gram matrices, mat-vecs, Gaussian elimination (for systematic LDPC
//! generators and MDS erasure decoding), power iteration (for spectral
//! learning-rate selection), and a handful of vector helpers. This module
//! keeps everything row-major and allocation-explicit so the hot path can
//! reuse buffers.

pub mod matrix;
pub mod ops;

pub use matrix::Matrix;
pub use ops::*;
