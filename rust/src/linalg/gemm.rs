//! Packed, register-tiled GEMM kernels and banded kernel drivers.
//!
//! PR 1's cache-blocked scalar GEMM streamed `GEMM_K_BLOCK`-row panels
//! of the right operand straight from its row-major storage and carried
//! a per-element `a == 0.0` branch. This module supplies the next level:
//!
//! * **Operand packing** — each `k` panel of the right operand is copied
//!   once into a contiguous scratch buffer laid out in [`NR`]-column
//!   micro-panels, so the innermost loop reads one unit-stride 8-vector
//!   per `k` step regardless of the output width.
//! * **Register tiling** — the micro-kernel computes an [`MR`]` × `[`NR`]
//!   (4 × 8) output tile with the `k` loop innermost. The 32 accumulators
//!   are spread across output *rows and columns*, never across `k`: per
//!   output element the summation is a single chain in ascending-`k`
//!   order, exactly the chain of the retained scalar reference
//!   ([`matmul_reference`]) and of PR 1's kernel. That invariant is what
//!   keeps every fixed-seed trajectory — and the thread-vs-sim parity
//!   pins — unchanged across kernel generations.
//! * **Sparsity-probing dispatch** — the dense path drops the
//!   per-element zero branch (a pure win on Gaussian data); operands
//!   that are ≥ 25% exact zeros (the `[I; P]` systematic generator's
//!   identity half, masked designs) keep PR 1's zero-skipping kernel,
//!   which for such inputs is both faster and the reference semantics.
//!
//! Equality contract: for real (finite, not-signed-zero-sensitive)
//! inputs every path is **bit-identical** to [`matmul_reference`]. The
//! only divergence class is adding an explicit `0.0 · b` term that the
//! zero-skipping reference skips, which can flip a signed zero or
//! propagate a NaN/∞ from the right operand — both outside the data
//! domain of this crate and invisible to `f64` equality on real data.
//! Property tests in `tests/prop_linalg.rs` pin the equality across
//! adversarial shapes and both dispatch paths.
//!
//! Parallel kernels split the *output* into contiguous row bands
//! (deterministic partition, identical per-row arithmetic in every
//! configuration) and run the bands on the process-lifetime
//! [`pool`](super::pool) instead of per-call scoped threads.

use std::cell::RefCell;

use super::matrix::Matrix;
use super::pool;

/// Register-tile rows (left-operand rows per micro-kernel call).
pub const MR: usize = 4;

/// Register-tile columns (right-operand columns per micro-kernel call).
pub const NR: usize = 8;

/// Rows of the right-hand operand packed per cache panel (64 rows of
/// ≤1k f64 columns ≈ L2-resident).
pub const GEMM_K_BLOCK: usize = 64;

/// Below this many multiply-adds a kernel runs single-threaded. With
/// the persistent pool, dispatch is a condvar wake (~1 µs) instead of
/// PR 1's ~10 µs scoped spawn/join, so the threshold drops from 2¹⁸ to
/// 2¹⁵ and mid-size step-loop matmuls parallelize too.
pub const PAR_FLOP_THRESHOLD: usize = 1 << 15;

/// Left operands whose exact-zero fraction reaches 1/4 route to the
/// zero-skipping scalar kernel instead of the packed dense kernel.
const SPARSE_ZERO_FRACTION: (usize, usize) = (1, 4); // (num, den)

/// Reusable packing scratch for the GEMM kernels. One buffer holds the
/// current `GEMM_K_BLOCK × cols` panel of the right operand in
/// micro-panel order; reusing it across calls (or taking the per-thread
/// default) keeps repeated GEMMs allocation-free at steady state.
#[derive(Debug, Default)]
pub struct GemmScratch {
    packed: Vec<f64>,
}

thread_local! {
    /// Per-thread fallback scratch for callers that do not thread their
    /// own. Pool workers and the master thread are long-lived, so the
    /// buffer amortizes to zero allocations.
    static PACK_TLS: RefCell<GemmScratch> = RefCell::new(GemmScratch::default());
}

fn with_scratch<R>(scratch: Option<&mut GemmScratch>, f: impl FnOnce(&mut GemmScratch) -> R) -> R {
    match scratch {
        Some(s) => f(s),
        None => PACK_TLS.with(|c| f(&mut c.borrow_mut())),
    }
}

/// Threads to use for a kernel costing `flops` multiply-adds.
pub(crate) fn threads_for(flops: usize) -> usize {
    if flops >= PAR_FLOP_THRESHOLD {
        pool::parallelism()
    } else {
        1
    }
}

/// Does the exact-zero fraction of `a` reach the sparse-dispatch
/// threshold? An `O(len)` probe, negligible against the `O(len · n)`
/// GEMM it steers.
pub(crate) fn probe_sparse(a: &Matrix) -> bool {
    let d = a.as_slice();
    if d.is_empty() {
        return false;
    }
    let zeros = d.iter().filter(|&&v| v == 0.0).count();
    let (num, den) = SPARSE_ZERO_FRACTION;
    zeros * den >= d.len() * num
}

/// Wrapper making a raw band base pointer shareable with pool tasks.
/// Sound: tasks write disjoint bands and finish before the caller
/// returns.
struct SyncPtr(*mut f64);
unsafe impl Sync for SyncPtr {}

/// Split `out` (a `rows x cols` row-major buffer) into contiguous row
/// bands and run `body(first_row, band)` on each, using up to `threads`
/// lanes of the persistent pool. `body` must compute each output row
/// independently — then the result is identical for every band split,
/// including the sequential `threads == 1` case and the pool-busy
/// inline fallback.
pub(crate) fn for_each_row_band<F>(
    out: &mut [f64],
    rows: usize,
    cols: usize,
    threads: usize,
    body: F,
) where
    F: Fn(usize, &mut [f64]) + Sync,
{
    debug_assert_eq!(out.len(), rows * cols);
    if rows == 0 || cols == 0 {
        return;
    }
    let threads = threads.clamp(1, rows);
    if threads == 1 {
        body(0, out);
        return;
    }
    let band_rows = rows.div_ceil(threads);
    let bands = rows.div_ceil(band_rows);
    let total = out.len();
    let base = SyncPtr(out.as_mut_ptr());
    pool::run(bands, &|b| {
        let start = b * band_rows * cols;
        let len = (band_rows * cols).min(total - start);
        // Safety: bands are disjoint slices of `out`, and `pool::run`
        // returns only after every task has finished.
        let band = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
        body(b * band_rows, band);
    });
}

// ---------------------------------------------------------------------
// Dense packed path
// ---------------------------------------------------------------------

/// Pack rows `kp..kend` of `b` into `packed` as [`NR`]-column
/// micro-panels: for column block `jb`, `klen` consecutive 8-vectors
/// `b[k][jb·NR .. jb·NR+NR]`, the ragged tail zero-padded. Every slot
/// is overwritten, so a recycled buffer needs no clearing.
///
/// Packing is pure data movement (no floating-point arithmetic), so it
/// can use pool lanes freely without touching the bit-identity
/// invariant — important for short-`m` GEMMs like the stacked moment
/// encode, where a serial pack would otherwise be a large Amdahl
/// fraction of the panel's wall time.
fn pack_b_panel(b: &Matrix, kp: usize, kend: usize, packed: &mut Vec<f64>, threads: usize) {
    let n = b.cols();
    let klen = kend - kp;
    let jblocks = n.div_ceil(NR);
    let panel_len = klen * NR;
    packed.resize(jblocks * panel_len, 0.0);
    if panel_len == 0 {
        return;
    }
    // Treat each micro-panel as one "row" of the destination; bands of
    // micro-panels are disjoint, so the copy parallelizes like a GEMM
    // band. Tiny panels stay inline (threads = 1).
    let threads = if klen.saturating_mul(n) >= PAR_FLOP_THRESHOLD { threads } else { 1 };
    for_each_row_band(packed, jblocks, panel_len, threads, |jb0, chunk| {
        for (dj, panel) in chunk.chunks_exact_mut(panel_len).enumerate() {
            let j0 = (jb0 + dj) * NR;
            let jw = NR.min(n - j0);
            for (kk, dst) in panel.chunks_exact_mut(NR).enumerate() {
                let row = b.row(kp + kk);
                dst[..jw].copy_from_slice(&row[j0..j0 + jw]);
                for d in &mut dst[jw..] {
                    *d = 0.0;
                }
            }
        }
    });
}

/// The register-tiled micro-kernel: accumulate an `RH × NR` tile with
/// the `k` loop innermost. Accumulators are spread across rows and
/// columns only — each `acc[r][j]` is a single ascending-`k` chain, so
/// the result is bit-identical to the scalar reference.
#[inline]
fn micro_kernel<const RH: usize>(arows: &[&[f64]; MR], bp: &[f64], acc: &mut [[f64; NR]; MR]) {
    for (kk, b8) in bp.chunks_exact(NR).enumerate() {
        for r in 0..RH {
            let av = arows[r][kk];
            for (c, &bv) in acc[r].iter_mut().zip(b8) {
                *c += av * bv;
            }
        }
    }
}

/// Accumulate one packed `k` panel into a row band of the output:
/// `band += A[row0.., kp..kend] · B[kp..kend, ..]` with `B` already
/// packed. Handles ragged row/column tails with narrower tiles (same
/// per-element order).
fn gemm_band_panel(
    a: &Matrix,
    row0: usize,
    band: &mut [f64],
    n: usize,
    kp: usize,
    kend: usize,
    packed: &[f64],
) {
    let band_rows = band.len() / n;
    let klen = kend - kp;
    if band_rows == 0 || klen == 0 {
        return;
    }
    let jblocks = n.div_ceil(NR);
    for jb in 0..jblocks {
        let j0 = jb * NR;
        let jw = NR.min(n - j0);
        let bp = &packed[jb * klen * NR..(jb + 1) * klen * NR];
        let mut i0 = 0;
        while i0 < band_rows {
            let rh = MR.min(band_rows - i0);
            let mut arows: [&[f64]; MR] = [&[]; MR];
            for (r, ar) in arows.iter_mut().enumerate().take(rh) {
                *ar = &a.row(row0 + i0 + r)[kp..kend];
            }
            // Tiles resume from the partial sums of earlier k panels;
            // padded lanes start at zero and are never stored.
            let mut acc = [[0.0f64; NR]; MR];
            for r in 0..rh {
                let row_off = (i0 + r) * n + j0;
                acc[r][..jw].copy_from_slice(&band[row_off..row_off + jw]);
            }
            match rh {
                4 => micro_kernel::<4>(&arows, bp, &mut acc),
                3 => micro_kernel::<3>(&arows, bp, &mut acc),
                2 => micro_kernel::<2>(&arows, bp, &mut acc),
                _ => micro_kernel::<1>(&arows, bp, &mut acc),
            }
            for r in 0..rh {
                let row_off = (i0 + r) * n + j0;
                band[row_off..row_off + jw].copy_from_slice(&acc[r][..jw]);
            }
            i0 += rh;
        }
    }
}

/// Packed dense GEMM over a pre-zeroed output buffer: per `k` panel,
/// pack once on the calling thread, then accumulate row bands in
/// parallel (barrier per panel — panels ascend, so per-element `k`
/// order is globally ascending).
fn matmul_packed_buf(
    a: &Matrix,
    b: &Matrix,
    out: &mut [f64],
    threads: usize,
    scratch: &mut GemmScratch,
) {
    let (m, kd) = a.shape();
    let n = b.cols();
    let mut kp = 0;
    while kp < kd {
        let kend = (kp + GEMM_K_BLOCK).min(kd);
        pack_b_panel(b, kp, kend, &mut scratch.packed, threads);
        let packed = &scratch.packed;
        for_each_row_band(out, m, n, threads, |row0, band| {
            gemm_band_panel(a, row0, band, n, kp, kend, packed);
        });
        kp = kend;
    }
}

// ---------------------------------------------------------------------
// Zero-skipping scalar path (PR 1's kernel, retained)
// ---------------------------------------------------------------------

/// PR 1's cache-blocked kernel with the per-element `a == 0.0` skip,
/// banded over the pool. Kept as the production path for left operands
/// with substantial exact sparsity — the systematic-generator encode's
/// `[I; P]` identity half chief among them.
fn matmul_skip_buf(a: &Matrix, b: &Matrix, out: &mut [f64], threads: usize) {
    let (m, kd) = a.shape();
    let n = b.cols();
    for_each_row_band(out, m, n, threads, |row0, band| {
        let band_rows = band.len() / n;
        let mut kp = 0;
        while kp < kd {
            let kend = (kp + GEMM_K_BLOCK).min(kd);
            for i in 0..band_rows {
                let arow = a.row(row0 + i);
                let orow = &mut band[i * n..(i + 1) * n];
                for (kk, &av) in arow.iter().enumerate().take(kend).skip(kp) {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = b.row(kk);
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
            kp = kend;
        }
    });
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Full GEMM into a raw row-major buffer (`a.rows() x b.cols()`, fully
/// overwritten): sparsity-probing dispatch between the packed dense
/// kernel and the zero-skipping scalar kernel, parallel over output row
/// bands when the problem amortizes a pool dispatch. Shapes must agree
/// (checked by the public [`Matrix`] wrappers).
pub(crate) fn matmul_dispatch_buf(
    a: &Matrix,
    b: &Matrix,
    out: &mut [f64],
    scratch: Option<&mut GemmScratch>,
) {
    let (m, kd) = a.shape();
    let n = b.cols();
    debug_assert_eq!(kd, b.rows());
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    if m == 0 || n == 0 || kd == 0 {
        return;
    }
    let flops = m.saturating_mul(kd).saturating_mul(n);
    let threads = threads_for(flops);
    if probe_sparse(a) {
        matmul_skip_buf(a, b, out, threads);
    } else {
        with_scratch(scratch, |s| matmul_packed_buf(a, b, out, threads, s));
    }
}

/// The packed register-tiled GEMM, forced (no sparsity dispatch):
/// `out = a · b`. Public so benches and property tests can time and pin
/// this path explicitly against [`matmul_reference`]. Panics on shape
/// mismatch.
pub fn matmul_packed_into(a: &Matrix, b: &Matrix, out: &mut Matrix, scratch: &mut GemmScratch) {
    assert_eq!(a.cols(), b.rows(), "matmul_packed_into: inner dimensions");
    assert_eq!(out.shape(), (a.rows(), b.cols()), "matmul_packed_into: output shape");
    let n = b.cols();
    let flops = a.rows().saturating_mul(a.cols()).saturating_mul(n);
    let threads = threads_for(flops);
    out.as_mut_slice().fill(0.0);
    if a.rows() == 0 || n == 0 || a.cols() == 0 {
        return;
    }
    matmul_packed_buf(a, b, out.as_mut_slice(), threads, scratch);
}

/// The retained scalar reference kernel: sequential `ikj` with the
/// `a == 0.0` skip — exactly the summation order (per output element,
/// ascending `k`) every production GEMM path must reproduce. This is
/// the pre-PR-1 semantics that all fixed-seed trajectories are pinned
/// to; benches report it as the `gemm_scalar_*` stages.
pub fn matmul_reference(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "matmul_reference: inner dimensions");
    assert_eq!(out.shape(), (a.rows(), b.cols()), "matmul_reference: output shape");
    let n = b.cols();
    out.as_mut_slice().fill(0.0);
    for i in 0..a.rows() {
        let arow = a.row(i);
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = b.row(kk);
            let orow = &mut out.as_mut_slice()[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Mat-vec kernels
// ---------------------------------------------------------------------

/// Mat-vec over `RH` consecutive rows sharing each `x` load. Every
/// output element keeps [`crate::linalg::ops::dot`]'s exact reduction
/// order: four `k`-strided lanes combined as `(s0 + s1) + (s2 + s3)`,
/// then the ragged tail — so this is bit-identical to the per-row
/// `dot` loop it replaces.
#[inline]
fn matvec_tile<const RH: usize>(m: &Matrix, x: &[f64], row0: usize, out: &mut [f64]) {
    let n = x.len();
    let chunks = n / 4;
    let mut rows: [&[f64]; RH] = [&[]; RH];
    for (r, slot) in rows.iter_mut().enumerate() {
        *slot = m.row(row0 + r);
    }
    let mut s = [[0.0f64; 4]; RH];
    for c in 0..chunks {
        let i = c * 4;
        let xs = &x[i..i + 4];
        for r in 0..RH {
            let a = &rows[r][i..i + 4];
            s[r][0] += a[0] * xs[0];
            s[r][1] += a[1] * xs[1];
            s[r][2] += a[2] * xs[2];
            s[r][3] += a[3] * xs[3];
        }
    }
    for r in 0..RH {
        let mut acc = (s[r][0] + s[r][1]) + (s[r][2] + s[r][3]);
        for i in chunks * 4..n {
            acc += rows[r][i] * x[i];
        }
        out[r] = acc;
    }
}

/// Mat-vec over a row band: `out[i] = m.row(row0 + i) · x`, processed
/// [`MR`] rows per pass (multi-accumulator column unrolling — `x` is
/// loaded once per 4 output rows).
pub(crate) fn matvec_band(m: &Matrix, x: &[f64], row0: usize, out: &mut [f64]) {
    let mut i = 0;
    while i < out.len() {
        let rh = MR.min(out.len() - i);
        match rh {
            4 => matvec_tile::<4>(m, x, row0 + i, &mut out[i..i + 4]),
            3 => matvec_tile::<3>(m, x, row0 + i, &mut out[i..i + 3]),
            2 => matvec_tile::<2>(m, x, row0 + i, &mut out[i..i + 2]),
            _ => matvec_tile::<1>(m, x, row0 + i, &mut out[i..i + 1]),
        }
        i += rh;
    }
}

/// Transposed mat-vec over a column band: accumulate
/// `out[j] += x[i] · m[i][col0 + j]` with `i` ascending and the
/// whole-row skip on `x[i] == 0.0` — the exact per-element order of the
/// pre-pool kernel. `out` must be zeroed by the caller.
pub(crate) fn matvec_t_band(m: &Matrix, x: &[f64], col0: usize, out: &mut [f64]) {
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &m.row(i)[col0..col0 + out.len()];
        for (o, &r) in out.iter_mut().zip(row) {
            *o += xi * r;
        }
    }
}

// ---------------------------------------------------------------------
// Gram kernels
// ---------------------------------------------------------------------

/// Dense register-tiled Gram band: `band[a][b] = Σ_i x[i][a0+a]·x[i][b]`
/// with the sample index `i` innermost and ascending (single chain per
/// element — the bit-identity invariant), tiled `MR × NR` over the
/// output and paneled over `i` for cache reuse. No zero branch.
pub(crate) fn gram_band_dense(x: &Matrix, a0: usize, band: &mut [f64]) {
    let k = x.cols();
    let band_rows = band.len() / k;
    let m = x.rows();
    let mut ip = 0;
    while ip < m {
        let iend = (ip + GEMM_K_BLOCK).min(m);
        let mut a = 0;
        while a < band_rows {
            let rh = MR.min(band_rows - a);
            let mut jb = 0;
            while jb < k {
                let jw = NR.min(k - jb);
                let mut acc = [[0.0f64; NR]; MR];
                for r in 0..rh {
                    let off = (a + r) * k + jb;
                    acc[r][..jw].copy_from_slice(&band[off..off + jw]);
                }
                for i in ip..iend {
                    let row = x.row(i);
                    let bvals = &row[jb..jb + jw];
                    for r in 0..rh {
                        let av = row[a0 + a + r];
                        for (c, &bv) in acc[r][..jw].iter_mut().zip(bvals) {
                            *c += av * bv;
                        }
                    }
                }
                for r in 0..rh {
                    let off = (a + r) * k + jb;
                    band[off..off + jw].copy_from_slice(&acc[r][..jw]);
                }
                jb += jw;
            }
            a += rh;
        }
        ip = iend;
    }
}

/// PR 1's zero-skipping Gram band, retained for sparse designs: for
/// each sample, rows of the band with `x[i][a0+da] == 0.0` are skipped
/// wholesale.
pub(crate) fn gram_band_skip(x: &Matrix, a0: usize, band: &mut [f64]) {
    let k = x.cols();
    let band_rows = band.len() / k;
    for i in 0..x.rows() {
        let row = x.row(i);
        for da in 0..band_rows {
            let ra = row[a0 + da];
            if ra == 0.0 {
                continue;
            }
            let grow = &mut band[da * k..(da + 1) * k];
            for (g, &rb) in grow.iter_mut().zip(row.iter()) {
                *g += ra * rb;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn packed_matches_reference_across_tile_and_panel_edges() {
        // Shapes straddle MR (4), NR (8), and GEMM_K_BLOCK (64)
        // boundaries, plus degenerate and prime dimensions.
        let mut rng = Rng::new(51);
        let shapes = [
            (1usize, 1usize, 1usize),
            (4, 64, 8),
            (5, 65, 9),
            (3, 63, 7),
            (8, 128, 16),
            (13, 17, 19),
            (12, 129, 24),
        ];
        let mut scratch = GemmScratch::default();
        for (m, k, n) in shapes {
            let a = Matrix::gaussian(m, k, &mut rng);
            let b = Matrix::gaussian(k, n, &mut rng);
            let mut want = Matrix::zeros(m, n);
            matmul_reference(&a, &b, &mut want);
            let mut got = Matrix::zeros(m, n);
            matmul_packed_into(&a, &b, &mut got, &mut scratch);
            assert_eq!(got.as_slice(), want.as_slice(), "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_overwrites_stale_output_and_reuses_scratch() {
        let mut rng = Rng::new(52);
        let a = Matrix::gaussian(9, 70, &mut rng);
        let b = Matrix::gaussian(70, 11, &mut rng);
        let mut want = Matrix::zeros(9, 11);
        matmul_reference(&a, &b, &mut want);
        let mut scratch = GemmScratch::default();
        let mut out = Matrix::zeros(9, 11);
        for _ in 0..3 {
            for v in out.as_mut_slice() {
                *v = f64::NAN;
            }
            matmul_packed_into(&a, &b, &mut out, &mut scratch);
            assert_eq!(out.as_slice(), want.as_slice());
        }
    }

    #[test]
    fn sparse_probe_thresholds() {
        let dense = Matrix::gaussian(10, 10, &mut Rng::new(53));
        assert!(!probe_sparse(&dense));
        assert!(probe_sparse(&Matrix::identity(8)));
        assert!(!probe_sparse(&Matrix::zeros(0, 5)));
        let mut quarter = Matrix::gaussian(4, 4, &mut Rng::new(54));
        for j in 0..4 {
            quarter[(0, j)] = 0.0; // exactly 1/4 zeros → sparse path
        }
        assert!(probe_sparse(&quarter));
    }

    #[test]
    fn matvec_band_matches_dot_per_row() {
        let mut rng = Rng::new(55);
        for (rows, cols) in [(1usize, 1usize), (4, 4), (5, 7), (11, 64), (3, 130)] {
            let m = Matrix::gaussian(rows, cols, &mut rng);
            let x = rng.gaussian_vec(cols);
            let mut out = vec![f64::NAN; rows];
            matvec_band(&m, &x, 0, &mut out);
            for i in 0..rows {
                let want = crate::linalg::ops::dot(m.row(i), &x);
                assert_eq!(out[i], want, "({rows},{cols}) row {i}");
            }
        }
    }

    #[test]
    fn pack_pads_ragged_column_tail_with_zeros() {
        let b = Matrix::gaussian(3, 10, &mut Rng::new(56));
        let mut packed = vec![f64::NAN; 4]; // stale, must be overwritten
        pack_b_panel(&b, 0, 3, &mut packed, 1);
        assert_eq!(packed.len(), 2 * 3 * NR);
        // Second micro-panel holds columns 8..10 then zero padding.
        for kk in 0..3 {
            let chunk = &packed[(3 + kk) * NR..(3 + kk + 1) * NR];
            assert_eq!(&chunk[..2], &b.row(kk)[8..10]);
            assert!(chunk[2..].iter().all(|&v| v == 0.0));
        }
    }
}
