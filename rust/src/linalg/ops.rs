//! Vector helpers, dense solves, and spectral estimation.

use super::matrix::Matrix;
use crate::error::{Error, Result};
use crate::rng::Rng;

/// Dot product. The 4-way unrolled accumulation lets LLVM vectorize and
/// keeps floating-point summation order deterministic.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `‖a - b‖₂`.
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

/// `out = a - b`.
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// `y += alpha * x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Scale in place.
pub fn scale(x: &mut [f64], alpha: f64) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Swap rows `a` and `b` of a matrix via whole-row slices.
fn swap_rows(m: &mut Matrix, a: usize, b: usize) {
    debug_assert_ne!(a, b);
    let cols = m.cols();
    let (lo, hi) = (a.min(b), a.max(b));
    let (head, tail) = m.as_mut_slice().split_at_mut(hi * cols);
    head[lo * cols..lo * cols + cols].swap_with_slice(&mut tail[..cols]);
}

/// Solve `A x = b` by Gaussian elimination with partial pivoting.
/// `A` is consumed as a copy; suitable for the small systems that arise in
/// systematic-generator construction and MDS erasure decoding. Row
/// updates run on whole-row slices (vectorizable axpy) but keep the
/// element order of the textbook loop, so results are unchanged
/// bit-for-bit.
pub fn solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = a.rows();
    if a.cols() != n || b.len() != n {
        return Err(Error::Linalg("solve: non-square system".into()));
    }
    let mut m = a.clone();
    let mut x = b.to_vec();
    // Scratch copy of the pivot-row tail so eliminations below can use
    // disjoint row slices.
    let mut piv_row = vec![0.0; n];
    for col in 0..n {
        // Partial pivot.
        let mut piv = col;
        let mut best = m[(col, col)].abs();
        for r in col + 1..n {
            let v = m[(r, col)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return Err(Error::Linalg(format!("solve: singular at column {col}")));
        }
        if piv != col {
            swap_rows(&mut m, col, piv);
            x.swap(col, piv);
        }
        let d = m[(col, col)];
        piv_row[col + 1..n].copy_from_slice(&m.row(col)[col + 1..n]);
        for r in col + 1..n {
            let f = m[(r, col)] / d;
            if f == 0.0 {
                continue;
            }
            let row = m.row_mut(r);
            row[col] = 0.0;
            axpy(-f, &piv_row[col + 1..n], &mut row[col + 1..n]);
            x[r] -= f * x[col];
        }
    }
    // Back substitution.
    for col in (0..n).rev() {
        let mut s = x[col];
        for j in col + 1..n {
            s -= m[(col, j)] * x[j];
        }
        x[col] = s / m[(col, col)];
    }
    Ok(x)
}

/// Matrix inverse via Gauss–Jordan with partial pivoting. As in
/// [`solve`], elimination runs as whole-row axpys with unchanged
/// element order (bit-identical results, fewer bounds checks).
pub fn invert(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Linalg("invert: non-square".into()));
    }
    let mut m = a.clone();
    let mut inv = Matrix::identity(n);
    let mut piv_m = vec![0.0; n];
    let mut piv_inv = vec![0.0; n];
    for col in 0..n {
        let mut piv = col;
        let mut best = m[(col, col)].abs();
        for r in col + 1..n {
            let v = m[(r, col)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return Err(Error::Linalg(format!("invert: singular at column {col}")));
        }
        if piv != col {
            swap_rows(&mut m, col, piv);
            swap_rows(&mut inv, col, piv);
        }
        let d = m[(col, col)];
        for v in m.row_mut(col) {
            *v /= d;
        }
        for v in inv.row_mut(col) {
            *v /= d;
        }
        piv_m.copy_from_slice(m.row(col));
        piv_inv.copy_from_slice(inv.row(col));
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m[(r, col)];
            if f == 0.0 {
                continue;
            }
            axpy(-f, &piv_m, m.row_mut(r));
            axpy(-f, &piv_inv, inv.row_mut(r));
        }
    }
    Ok(inv)
}

/// Rank of a matrix via row echelon reduction with partial pivoting.
pub fn rank(a: &Matrix, tol: f64) -> usize {
    let (rows, cols) = a.shape();
    let mut m = a.clone();
    let mut rank = 0;
    let mut row = 0;
    for col in 0..cols {
        if row >= rows {
            break;
        }
        let mut piv = row;
        let mut best = m[(row, col)].abs();
        for r in row + 1..rows {
            let v = m[(r, col)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best <= tol {
            continue;
        }
        if piv != row {
            for j in 0..cols {
                let t = m[(row, j)];
                m[(row, j)] = m[(piv, j)];
                m[(piv, j)] = t;
            }
        }
        let d = m[(row, col)];
        for r in row + 1..rows {
            let f = m[(r, col)] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..cols {
                let v = m[(row, j)];
                m[(r, j)] -= f * v;
            }
        }
        rank += 1;
        row += 1;
    }
    rank
}

/// Largest eigenvalue of a symmetric PSD matrix via power iteration.
/// Used to pick the spectral step size `η = 1/λ_max(XᵀX)`.
pub fn lambda_max(m: &Matrix, iters: usize, seed: u64) -> f64 {
    let n = m.rows();
    debug_assert_eq!(m.cols(), n);
    let mut rng = Rng::new(seed);
    let mut v = rng.gaussian_vec(n);
    let mut w = vec![0.0; n];
    let mut lambda = 0.0;
    for _ in 0..iters {
        m.matvec_into(&v, &mut w);
        let nrm = norm2(&w);
        if nrm == 0.0 {
            return 0.0;
        }
        for (vi, &wi) in v.iter_mut().zip(&w) {
            *vi = wi / nrm;
        }
        lambda = nrm;
    }
    // Final Rayleigh quotient for accuracy.
    m.matvec_into(&v, &mut w);
    let rq = dot(&v, &w) / dot(&v, &v);
    if rq.is_finite() {
        rq
    } else {
        lambda
    }
}

/// 2-norm condition number estimate of a square matrix: power iteration on
/// `AᵀA` for `σ_max` and inverse iteration (via [`solve`] on `AᵀA`) for
/// `σ_min`. Used to demonstrate the Vandermonde conditioning pathology the
/// paper cites as a motivation for LDPC codes. A numerically singular
/// matrix reports `f64::INFINITY` rather than an error.
pub fn condition_number(a: &Matrix, iters: usize, seed: u64) -> Result<f64> {
    // gram() forms AᵀA directly (no transpose allocation) through the
    // band-parallel kernel; term order matches transpose().matmul()
    // exactly, so estimates are unchanged.
    let ata = a.gram();
    let smax2 = lambda_max(&ata, iters, seed);
    // Inverse power iteration: v <- (AᵀA)^{-1} v normalized.
    let n = ata.rows();
    let mut rng = Rng::new(seed ^ 0xDEAD_BEEF);
    let mut v = rng.gaussian_vec(n);
    let nrm0 = norm2(&v);
    scale(&mut v, 1.0 / nrm0);
    let mut mu = 0.0;
    for _ in 0..iters {
        let w = match solve(&ata, &v) {
            Ok(w) => w,
            // Pivot below tolerance: AᵀA is numerically singular.
            Err(_) => return Ok(f64::INFINITY),
        };
        let nrm = norm2(&w);
        if !nrm.is_finite() || nrm == 0.0 {
            return Ok(f64::INFINITY);
        }
        for (vi, &wi) in v.iter_mut().zip(&w) {
            *vi = wi / nrm;
        }
        mu = nrm; // ≈ 1/λ_min
    }
    let smin2 = 1.0 / mu;
    Ok((smax2 / smin2).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(4);
        for n in [0, 1, 3, 4, 7, 64, 100] {
            let a = rng.gaussian_vec(n);
            let b = rng.gaussian_vec(n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn solve_roundtrip() {
        let mut rng = Rng::new(5);
        for n in [1, 2, 5, 20] {
            let a = Matrix::gaussian(n, n, &mut rng);
            let x_true = rng.gaussian_vec(n);
            let b = a.matvec(&x_true);
            let x = solve(&a, &b).unwrap();
            for (g, w) in x.iter().zip(&x_true) {
                assert!((g - w).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn solve_singular_errors() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]).unwrap();
        assert!(solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn invert_roundtrip() {
        let mut rng = Rng::new(6);
        let a = Matrix::gaussian(8, 8, &mut rng);
        let inv = invert(&a).unwrap();
        let prod = a.matmul(&inv).unwrap();
        for i in 0..8 {
            for j in 0..8 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn rank_detects_deficiency() {
        let full = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]).unwrap();
        assert_eq!(rank(&full, 1e-10), 2);
        let def = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]).unwrap();
        assert_eq!(rank(&def, 1e-10), 1);
        let wide = Matrix::from_rows(&[vec![1.0, 0.0, 1.0], vec![0.0, 1.0, 1.0]]).unwrap();
        assert_eq!(rank(&wide, 1e-10), 2);
    }

    #[test]
    fn lambda_max_diagonal() {
        let m = Matrix::from_rows(&[
            vec![3.0, 0.0, 0.0],
            vec![0.0, 7.0, 0.0],
            vec![0.0, 0.0, 1.0],
        ])
        .unwrap();
        let l = lambda_max(&m, 200, 1);
        assert!((l - 7.0).abs() < 1e-6, "lambda {l}");
    }

    #[test]
    fn lambda_max_gram_bounds() {
        // For an m x k standard Gaussian X, lambda_max(X^T X) concentrates
        // near (sqrt(m)+sqrt(k))^2.
        let mut rng = Rng::new(8);
        let x = Matrix::gaussian(200, 50, &mut rng);
        let g = x.gram();
        let l = lambda_max(&g, 300, 2);
        let expect = (200f64.sqrt() + 50f64.sqrt()).powi(2);
        assert!(l > 0.5 * expect && l < 1.5 * expect, "lambda {l} vs {expect}");
    }

    #[test]
    fn condition_number_identity() {
        let i = Matrix::identity(6);
        let c = condition_number(&i, 100, 3).unwrap();
        assert!((c - 1.0).abs() < 1e-6, "cond {c}");
    }

    #[test]
    fn condition_number_scaled_diag() {
        let m = Matrix::from_rows(&[vec![10.0, 0.0], vec![0.0, 0.1]]).unwrap();
        let c = condition_number(&m, 200, 4).unwrap();
        assert!((c - 100.0).abs() / 100.0 < 0.01, "cond {c}");
    }

    /// Textbook Gauss–Jordan exactly as shipped before the slice/axpy
    /// restructuring. `invert` feeds systematic-generator construction
    /// (and therefore every fixed-seed trajectory), so the restructured
    /// kernel must match this bit-for-bit, not approximately.
    fn invert_reference(a: &Matrix) -> Matrix {
        let n = a.rows();
        let mut m = a.clone();
        let mut inv = Matrix::identity(n);
        for col in 0..n {
            let mut piv = col;
            let mut best = m[(col, col)].abs();
            for r in col + 1..n {
                let v = m[(r, col)].abs();
                if v > best {
                    best = v;
                    piv = r;
                }
            }
            assert!(best >= 1e-12, "reference: singular");
            if piv != col {
                for j in 0..n {
                    let t = m[(col, j)];
                    m[(col, j)] = m[(piv, j)];
                    m[(piv, j)] = t;
                    let t = inv[(col, j)];
                    inv[(col, j)] = inv[(piv, j)];
                    inv[(piv, j)] = t;
                }
            }
            let d = m[(col, col)];
            for j in 0..n {
                m[(col, j)] /= d;
                inv[(col, j)] /= d;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = m[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    let mv = m[(col, j)];
                    m[(r, j)] -= f * mv;
                    let iv = inv[(col, j)];
                    inv[(r, j)] -= f * iv;
                }
            }
        }
        inv
    }

    #[test]
    fn invert_bitwise_matches_textbook_order() {
        let mut rng = Rng::new(23);
        for n in [1usize, 2, 5, 12, 30] {
            let a = Matrix::gaussian(n, n, &mut rng);
            let got = invert(&a).unwrap();
            let want = invert_reference(&a);
            assert_eq!(got.as_slice(), want.as_slice(), "n={n}");
        }
    }

    #[test]
    fn solve_bitwise_matches_textbook_order() {
        // Reference: elimination with in-place reads of the pivot row,
        // exactly the pre-restructuring loop.
        let mut rng = Rng::new(29);
        for n in [1usize, 3, 8, 25] {
            let a = Matrix::gaussian(n, n, &mut rng);
            let b = rng.gaussian_vec(n);
            let got = solve(&a, &b).unwrap();
            let want = {
                let mut m = a.clone();
                let mut x = b.clone();
                for col in 0..n {
                    let mut piv = col;
                    let mut best = m[(col, col)].abs();
                    for r in col + 1..n {
                        let v = m[(r, col)].abs();
                        if v > best {
                            best = v;
                            piv = r;
                        }
                    }
                    assert!(best >= 1e-12);
                    if piv != col {
                        for j in 0..n {
                            let t = m[(col, j)];
                            m[(col, j)] = m[(piv, j)];
                            m[(piv, j)] = t;
                        }
                        x.swap(col, piv);
                    }
                    let d = m[(col, col)];
                    for r in col + 1..n {
                        let f = m[(r, col)] / d;
                        if f == 0.0 {
                            continue;
                        }
                        m[(r, col)] = 0.0;
                        for j in col + 1..n {
                            let v = m[(col, j)];
                            m[(r, j)] -= f * v;
                        }
                        x[r] -= f * x[col];
                    }
                }
                for col in (0..n).rev() {
                    let mut s = x[col];
                    for j in col + 1..n {
                        s -= m[(col, j)] * x[j];
                    }
                    x[col] = s / m[(col, col)];
                }
                x
            };
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![6.0, 12.0]);
    }
}
