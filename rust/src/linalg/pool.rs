//! Process-lifetime worker pool for the linalg kernels.
//!
//! PR 1 parallelized the GEMM-shaped kernels with `std::thread::scope`,
//! which spawns and joins OS threads *per call* — roughly 10 µs of fixed
//! overhead that forced a high `PAR_FLOP_THRESHOLD` and kept mid-size
//! step-loop matmuls sequential. This module replaces scoped spawning
//! with a lazily-initialized pool of persistent workers (hand-rolled on
//! `std::sync::{Mutex, Condvar}`; the crate's only dependency is libc):
//! dispatch is one mutex lock plus a condvar wake, so parallelism pays
//! off one to two orders of magnitude earlier.
//!
//! Design constraints, in order:
//!
//! * **Bit-identity is the caller's invariant, not ours.** The pool runs
//!   `body(i)` for every `i < tasks` with no ordering guarantee; linalg
//!   kernels stay deterministic because every task writes a disjoint
//!   output band whose contents do not depend on the split (see
//!   `gemm::for_each_row_band`).
//! * **Never deadlock, never queue.** If a job is already in flight —
//!   another thread is mid-GEMM, or the caller *is* a pool worker — the
//!   submitter simply runs its tasks inline on its own thread. The
//!   OS-thread cluster's 40 workers therefore never serialize behind
//!   one shared pool (they additionally opt out wholesale via
//!   [`set_thread_inline`]), and a kernel nested inside a pool task
//!   degrades to the sequential path instead of self-waiting.
//! * **Spawn once per process.** Workers are created on first parallel
//!   use and reused forever; [`threads_spawned`] exposes the count so
//!   tests can pin the spawn-once behavior.
//!
//! The submitting thread always participates in executing tasks, so the
//! pool needs only `available_parallelism() - 1` workers and a job makes
//! progress even if every worker spawn failed.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Lifetime-erased reference to the job body. The `'static` is a lie
/// told only inside this module: [`run`] does not return until every
/// task has finished, so the borrow it erases strictly outlives every
/// use. (`&(dyn Fn + Sync)` is `Send + Copy`, which is what lets the
/// job sit in the shared mutex.)
#[derive(Clone, Copy)]
struct JobBody(&'static (dyn Fn(usize) + Sync));

/// One in-flight batch of tasks.
struct Job {
    body: JobBody,
    tasks: usize,
    /// Next unclaimed task index.
    next: usize,
    /// Tasks claimed or unclaimed but not yet finished.
    pending: usize,
    /// First task panic payload (the submitter resumes it after the
    /// job drains, preserving the original message/backtrace payload
    /// exactly as `std::thread::scope` used to).
    panic: Option<Box<dyn Any + Send>>,
}

struct Pool {
    state: Mutex<Option<Job>>,
    /// Workers wait here for a job with unclaimed tasks.
    work: Condvar,
    /// The submitter waits here for `pending == 0`.
    done: Condvar,
    /// Worker threads actually running (spawn failures excluded); set
    /// once during init, read by `parallelism()`.
    workers: AtomicUsize,
}

static POOL: OnceLock<Option<&'static Pool>> = OnceLock::new();
static THREADS_SPAWNED: AtomicUsize = AtomicUsize::new(0);
static DISPATCHES: AtomicU64 = AtomicU64::new(0);
static INLINE_FALLBACKS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Threads that must never submit to (or wait on) the pool: the
    /// pool's own workers and the coordinator's cluster worker threads,
    /// which are already running `w`-way parallel.
    static INLINE_ONLY: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Mark (or unmark) the current thread as inline-only: linalg kernels
/// called from it run sequentially instead of dispatching to the shared
/// pool. The coordinator marks its cluster worker threads — forty
/// threads each running their own shard mat-vec gain nothing from a
/// single shared pool and would contend on its lock.
pub fn set_thread_inline(inline: bool) {
    INLINE_ONLY.with(|c| c.set(inline));
}

fn pool() -> Option<&'static Pool> {
    *POOL.get_or_init(|| {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if n < 2 {
            return None;
        }
        let pool: &'static Pool = Box::leak(Box::new(Pool {
            state: Mutex::new(None),
            work: Condvar::new(),
            done: Condvar::new(),
            workers: AtomicUsize::new(0),
        }));
        let mut spawned = 0;
        for i in 0..n - 1 {
            let ok = std::thread::Builder::new()
                .name(format!("linalg-pool-{i}"))
                .spawn(move || {
                    set_thread_inline(true);
                    worker_loop(pool);
                })
                .is_ok();
            if ok {
                spawned += 1;
            }
        }
        THREADS_SPAWNED.store(spawned, Ordering::Relaxed);
        // Informational only: claiming is dynamic, so a partial spawn
        // reduces parallelism, never correctness.
        pool.workers.store(spawned, Ordering::Relaxed);
        if spawned == 0 {
            None
        } else {
            Some(pool)
        }
    })
}

fn worker_loop(pool: &'static Pool) {
    let mut state = pool.state.lock().unwrap();
    loop {
        let claim = match state.as_mut() {
            Some(job) if job.next < job.tasks => {
                let i = job.next;
                job.next += 1;
                Some((i, job.body))
            }
            _ => None,
        };
        match claim {
            Some((i, body)) => {
                drop(state);
                // `run` keeps the body alive until the job drains.
                let result = catch_unwind(AssertUnwindSafe(|| (body.0)(i)));
                state = pool.state.lock().unwrap();
                let job = state.as_mut().expect("job outlives its tasks");
                if let Err(payload) = result {
                    job.panic.get_or_insert(payload);
                }
                job.pending -= 1;
                if job.pending == 0 {
                    pool.done.notify_all();
                }
            }
            None => state = pool.work.wait(state).unwrap(),
        }
    }
}

/// Run `body(0), …, body(tasks - 1)`, in parallel on the shared pool
/// when it is free and this thread may use it, inline on the calling
/// thread otherwise. Returns only after every task has finished (this
/// is what makes the internal lifetime erasure sound). If any task
/// panicked, the first panic payload is resumed on the calling thread
/// (matching `std::thread::scope` semantics).
///
/// Tasks must be independent: no ordering between them is guaranteed,
/// and any subset may run on the calling thread.
pub fn run(tasks: usize, body: &(dyn Fn(usize) + Sync)) {
    let run_inline = || {
        for i in 0..tasks {
            body(i);
        }
    };
    if tasks <= 1 || INLINE_ONLY.with(|c| c.get()) {
        run_inline();
        return;
    }
    let Some(pool) = pool() else {
        run_inline();
        return;
    };
    // Lifetime erasure: see JobBody. The transmute only widens the
    // borrow's lifetime to 'static; `run` blocks until the job drains.
    let erased: &'static (dyn Fn(usize) + Sync) =
        unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(body) };
    let body_ptr = JobBody(erased);
    {
        let mut state = pool.state.lock().unwrap();
        if state.is_some() {
            // A job is in flight (possibly our own, if we are nested
            // inside a pool task): degrade to the sequential path
            // rather than queueing or self-waiting.
            drop(state);
            INLINE_FALLBACKS.fetch_add(1, Ordering::Relaxed);
            run_inline();
            return;
        }
        *state = Some(Job {
            body: body_ptr,
            tasks,
            next: 0,
            pending: tasks,
            panic: None,
        });
    }
    DISPATCHES.fetch_add(1, Ordering::Relaxed);
    pool.work.notify_all();

    // The submitter participates: claim and run tasks like a worker.
    loop {
        let i = {
            let mut state = pool.state.lock().unwrap();
            let job = state.as_mut().expect("submitter's job is installed");
            if job.next >= job.tasks {
                break;
            }
            let i = job.next;
            job.next += 1;
            i
        };
        let result = catch_unwind(AssertUnwindSafe(|| body(i)));
        let mut state = pool.state.lock().unwrap();
        let job = state.as_mut().expect("submitter's job is installed");
        if let Err(payload) = result {
            job.panic.get_or_insert(payload);
        }
        job.pending -= 1;
        if job.pending == 0 {
            pool.done.notify_all();
        }
    }

    // Wait for workers to finish the tasks they claimed, then retire
    // the job slot.
    let mut state = pool.state.lock().unwrap();
    while state.as_ref().expect("job retired only here").pending > 0 {
        state = pool.done.wait(state).unwrap();
    }
    let job = state.take().expect("job retired only here");
    drop(state);
    pool.work.notify_all(); // wake workers parked mid-job so they re-park cleanly
    if let Some(payload) = job.panic {
        resume_unwind(payload);
    }
}

/// Number of lanes a pooled kernel can use: the persistent workers plus
/// the submitting thread. 1 when the host is single-core or the pool
/// could not spawn.
pub fn parallelism() -> usize {
    match pool() {
        Some(p) => p.workers.load(Ordering::Relaxed) + 1,
        None => 1,
    }
}

/// Force pool initialization (worker spawn) now, so the first timed
/// gradient step does not pay it.
pub fn prewarm() {
    let _ = pool();
}

/// Total pool worker threads ever spawned by this process — constant
/// after first use (the spawn-once invariant tests pin).
pub fn threads_spawned() -> usize {
    let _ = pool();
    THREADS_SPAWNED.load(Ordering::Relaxed)
}

/// Jobs dispatched to the pool (parallel runs).
pub fn dispatches() -> u64 {
    DISPATCHES.load(Ordering::Relaxed)
}

/// `run` calls that found the pool busy and ran inline instead.
pub fn inline_fallbacks() -> u64 {
    INLINE_FALLBACKS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_task_exactly_once() {
        for tasks in [0usize, 1, 2, 3, 7, 64] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            run(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} of {tasks}");
            }
        }
    }

    #[test]
    fn nested_run_degrades_inline_without_deadlock() {
        let outer = AtomicUsize::new(0);
        let inner = AtomicUsize::new(0);
        run(4, &|_| {
            outer.fetch_add(1, Ordering::Relaxed);
            run(4, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(outer.load(Ordering::Relaxed), 4);
        assert_eq!(inner.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn spawns_once_and_reuses_threads() {
        run(8, &|_| {});
        let after_first = threads_spawned();
        assert!(after_first <= parallelism());
        for _ in 0..50 {
            run(8, &|i| {
                std::hint::black_box(i * i);
            });
        }
        assert_eq!(
            threads_spawned(),
            after_first,
            "pool must reuse its workers, not respawn"
        );
        if parallelism() > 1 {
            assert_eq!(after_first, parallelism() - 1);
            assert!(dispatches() > 0, "multi-core host must dispatch to the pool");
        }
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        // Two threads hammer `run` simultaneously: one wins the pool,
        // the other falls back inline — both must finish all tasks.
        let h: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(|| {
                    let count = AtomicUsize::new(0);
                    for _ in 0..20 {
                        run(8, &|_| {
                            count.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                    count.load(Ordering::Relaxed)
                })
            })
            .collect();
        for th in h {
            assert_eq!(th.join().unwrap(), 160);
        }
    }

    #[test]
    fn inline_only_thread_runs_every_task_on_itself() {
        std::thread::spawn(|| {
            set_thread_inline(true);
            let me = std::thread::current().id();
            let count = AtomicUsize::new(0);
            run(16, &|_| {
                assert_eq!(
                    std::thread::current().id(),
                    me,
                    "task escaped an inline-only thread"
                );
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), 16);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn task_panic_propagates_to_submitter() {
        let result = catch_unwind(|| {
            run(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        });
        let payload = result.expect_err("panic in a task must reach the submitter");
        // The original payload survives the pool (scope semantics).
        assert_eq!(payload.downcast_ref::<&str>().copied(), Some("boom"));
        // The pool must remain usable afterwards.
        let count = AtomicUsize::new(0);
        run(4, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 4);
    }
}
