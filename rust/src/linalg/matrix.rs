//! Row-major dense matrix over `f64`.

use super::gemm::{self, GemmScratch};
use crate::error::{Error, Result};
use crate::rng::Rng;

/// A dense row-major matrix of `f64`.
///
/// Deliberately minimal: the crate's numerics are dominated by mat-vec and
/// small dense solves, so we favour explicit loops (which LLVM vectorizes
/// well) over a BLAS dependency that is unavailable in this offline build.
/// The GEMM-shaped entry points ([`Matrix::matmul_into`],
/// [`Matrix::gram_into`], [`Matrix::matvec_into`]) run on the packed,
/// register-tiled kernels of [`super::gemm`], parallel over output bands
/// on the persistent [`super::pool`]; every output element is a single
/// ascending-index summation chain in every configuration, so results
/// are bit-identical to the sequential scalar kernels
/// ([`gemm::matmul_reference`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Square tile edge for the cache-blocked transpose.
const TRANSPOSE_BLOCK: usize = 32;

/// `rows * cols` with overflow reported as a linalg error (adversarial
/// shapes must not wrap in release builds).
fn checked_len(rows: usize, cols: usize) -> Result<usize> {
    rows.checked_mul(cols)
        .ok_or_else(|| Error::Linalg(format!("shape {rows}x{cols} overflows usize")))
}

impl Matrix {
    /// All-zeros matrix. Panics on shape overflow; use
    /// [`Matrix::try_zeros`] where the shape is untrusted.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self::try_zeros(rows, cols).expect("matrix shape overflows usize")
    }

    /// All-zeros matrix with a checked `rows * cols` (adversarial shapes
    /// surface as [`Error::Linalg`] instead of wrapping or aborting).
    pub fn try_zeros(rows: usize, cols: usize) -> Result<Self> {
        let len = checked_len(rows, cols)?;
        Ok(Matrix { rows, cols, data: vec![0.0; len] })
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        let need = checked_len(rows, cols)?;
        if data.len() != need {
            return Err(Error::Linalg(format!(
                "from_vec: {}x{} needs {} elements, got {}",
                rows,
                cols,
                need,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        if rows.iter().any(|x| x.len() != c) {
            return Err(Error::Linalg("from_rows: ragged rows".into()));
        }
        let mut data = Vec::with_capacity(checked_len(r, c)?);
        for row in rows {
            data.extend_from_slice(row);
        }
        Matrix::from_vec(r, c, data)
    }

    /// Matrix with i.i.d. standard-normal entries.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: rng.gaussian_vec(rows * cols) }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Shape as `(rows, cols)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow the underlying row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutably borrow the underlying row-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the underlying buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Matrix transpose (new allocation). Walks `TRANSPOSE_BLOCK`-square
    /// tiles so both source reads and destination writes stay within a
    /// few cache lines per tile, instead of striding the destination by
    /// the full row length on every element.
    pub fn transpose(&self) -> Matrix {
        let (r, c) = (self.rows, self.cols);
        let mut t = Matrix::zeros(c, r);
        const B: usize = TRANSPOSE_BLOCK;
        let mut ib = 0;
        while ib < r {
            let imax = (ib + B).min(r);
            let mut jb = 0;
            while jb < c {
                let jmax = (jb + B).min(c);
                for i in ib..imax {
                    let src = &self.data[i * c..i * c + c];
                    for j in jb..jmax {
                        t.data[j * r + i] = src[j];
                    }
                }
                jb = jmax;
            }
            ib = imax;
        }
        t
    }

    /// Mat-vec `self * x`, writing into `out` (len = rows). Runs the
    /// multi-accumulator row-tiled kernel ([`gemm::MR`] rows share each
    /// `x` load), banded over the pool for large shapes; per output
    /// element the reduction order is exactly [`super::ops::dot`]'s, so
    /// results are bit-identical at every size and thread count.
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(out.len(), self.rows);
        let threads = gemm::threads_for(self.rows.saturating_mul(self.cols));
        if threads == 1 {
            gemm::matvec_band(self, x, 0, out);
        } else {
            gemm::for_each_row_band(out, self.rows, 1, threads, |row0, band| {
                gemm::matvec_band(self, x, row0, band);
            });
        }
    }

    /// Mat-vec `self * x` (allocates).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(x, &mut out);
        out
    }

    /// Transposed mat-vec `selfᵀ * x`, writing into `out` (len = cols;
    /// x has len = rows). Streams through rows so access stays
    /// contiguous; large shapes split the *output columns* into pool
    /// bands — the accumulation index `i` still ascends per element, so
    /// results are bit-identical to the sequential kernel.
    pub fn matvec_t_into(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        let threads = gemm::threads_for(self.rows.saturating_mul(self.cols));
        if threads == 1 {
            gemm::matvec_t_band(self, x, 0, out);
        } else {
            gemm::for_each_row_band(out, self.cols, 1, threads, |col0, band| {
                gemm::matvec_t_band(self, x, col0, band);
            });
        }
    }

    /// Transposed mat-vec `selfᵀ * x` (allocates; x has len = rows).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut out);
        out
    }

    /// Shape checks shared by the GEMM entry points.
    fn check_matmul_shapes(&self, other: &Matrix, out: &Matrix) -> Result<()> {
        if self.cols != other.rows {
            return Err(Error::Linalg(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        if out.shape() != (self.rows, other.cols) {
            return Err(Error::Linalg(format!(
                "matmul_into: output is {}x{}, need {}x{}",
                out.rows, out.cols, self.rows, other.cols
            )));
        }
        Ok(())
    }

    /// Dense matrix product `self * other` written into `out`
    /// (`self.rows x other.cols`, fully overwritten).
    ///
    /// Dispatches on a sparsity probe of `self`: mostly-dense operands
    /// run the packed register-tiled kernel (no per-element zero
    /// branch), operands with ≥ 25% exact zeros (e.g. the `[I; P]`
    /// systematic generator) keep the zero-skipping scalar kernel. Row
    /// bands of the output run on the persistent pool when the problem
    /// amortizes a dispatch. Per output element the `k` summation order
    /// is ascending in every configuration, so the product is
    /// bit-identical to the sequential reference kernel
    /// ([`gemm::matmul_reference`]). Packing scratch comes from a
    /// per-thread buffer; use [`Matrix::matmul_into_with`] to thread an
    /// explicit one.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) -> Result<()> {
        self.check_matmul_shapes(other, out)?;
        gemm::matmul_dispatch_buf(self, other, &mut out.data, None);
        Ok(())
    }

    /// [`Matrix::matmul_into`] with caller-owned packing scratch, for
    /// call sites that keep GEMM-shaped work allocation-free (the
    /// encoder's stacked moment GEMM, decode arenas).
    pub fn matmul_into_with(
        &self,
        other: &Matrix,
        out: &mut Matrix,
        scratch: &mut GemmScratch,
    ) -> Result<()> {
        self.check_matmul_shapes(other, out)?;
        gemm::matmul_dispatch_buf(self, other, &mut out.data, Some(scratch));
        Ok(())
    }

    /// GEMM into a raw row-major buffer of length
    /// `self.rows * other.cols` — lets callers compute directly into a
    /// region of a larger allocation (e.g. the parity half of a stacked
    /// codeword matrix) without a temporary.
    pub(crate) fn matmul_into_buf(
        &self,
        other: &Matrix,
        out: &mut [f64],
        scratch: Option<&mut GemmScratch>,
    ) -> Result<()> {
        if self.cols != other.rows {
            return Err(Error::Linalg(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let need = checked_len(self.rows, other.cols)?;
        if out.len() != need {
            return Err(Error::Linalg(format!(
                "matmul_into_buf: buffer holds {}, need {need}",
                out.len()
            )));
        }
        gemm::matmul_dispatch_buf(self, other, out, scratch);
        Ok(())
    }

    /// Dense matrix product `self * other` (allocates).
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        let mut out = Matrix::try_zeros(self.rows, other.cols)?;
        self.matmul_into(other, &mut out)?;
        Ok(out)
    }

    /// Gram matrix `selfᵀ * self` written into `out` (`cols x cols`,
    /// fully overwritten). Parallel over output row bands on the
    /// persistent pool; the dense path is register-tiled with the
    /// sample index innermost, the sparse path (≥ 25% exact zeros)
    /// keeps the zero-skipping kernel. Per output element the sample
    /// index ascends in every configuration, so the result is
    /// bit-identical to the sequential kernel.
    pub fn gram_into(&self, out: &mut Matrix) -> Result<()> {
        let k = self.cols;
        if out.shape() != (k, k) {
            return Err(Error::Linalg(format!(
                "gram_into: output is {}x{}, need {k}x{k}",
                out.rows, out.cols
            )));
        }
        out.data.fill(0.0);
        if k == 0 || self.rows == 0 {
            return Ok(());
        }
        let flops = self.rows.saturating_mul(k).saturating_mul(k);
        let threads = gemm::threads_for(flops);
        if gemm::probe_sparse(self) {
            gemm::for_each_row_band(&mut out.data, k, k, threads, |a0, band| {
                gemm::gram_band_skip(self, a0, band);
            });
        } else {
            gemm::for_each_row_band(&mut out.data, k, k, threads, |a0, band| {
                gemm::gram_band_dense(self, a0, band);
            });
        }
        Ok(())
    }

    /// Gram matrix `selfᵀ * self` (symmetric `cols x cols`).
    pub fn gram(&self) -> Matrix {
        let mut g = Matrix::zeros(self.cols, self.cols);
        self.gram_into(&mut g).expect("output shape matches by construction");
        g
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Select a subset of columns into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (c, &j) in idx.iter().enumerate() {
                dst[c] = src[j];
            }
        }
        out
    }

    /// Vertically stack two matrices with equal column counts.
    pub fn vstack(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.cols {
            return Err(Error::Linalg("vstack: column mismatch".into()));
        }
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Frobenius norm.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Pad with zero rows/cols to the given shape (≥ current shape).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Result<Matrix> {
        if rows < self.rows || cols < self.cols {
            return Err(Error::Linalg("pad_to: target smaller than source".into()));
        }
        let mut out = Matrix::try_zeros(rows, cols)?;
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        Ok(out)
    }

    /// Convert to `f32` row-major (for the PJRT/f32 artifact path).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m22() -> Matrix {
        Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap()
    }

    /// The pre-band-parallel reference kernel: sequential ikj with the
    /// same zero-skip. The production GEMM must match it bit-for-bit at
    /// every size (the fixed-seed trajectory invariant).
    fn matmul_reference(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for k in 0..a.cols() {
                let av = a[(i, k)];
                if av == 0.0 {
                    continue;
                }
                for j in 0..b.cols() {
                    out[(i, j)] += av * b[(k, j)];
                }
            }
        }
        out
    }

    #[test]
    fn matvec_basic() {
        assert_eq!(m22().matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(7, 5, &mut rng);
        let x = rng.gaussian_vec(7);
        let got = a.matvec_t(&x);
        let want = a.transpose().matvec(&x);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_into_overwrites_stale_output() {
        let mut rng = Rng::new(21);
        let a = Matrix::gaussian(6, 4, &mut rng);
        let x = rng.gaussian_vec(6);
        let want = a.matvec_t(&x);
        let mut out = vec![f64::NAN; 4];
        a.matvec_t_into(&x, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn matmul_identity() {
        let a = m22();
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i).unwrap(), a);
        assert_eq!(i.matmul(&a).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = m22();
        let b = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c, Matrix::from_rows(&[vec![2.0, 1.0], vec![4.0, 3.0]]).unwrap());
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = m22();
        let b = Matrix::zeros(3, 2);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn matmul_into_shape_checked() {
        let a = m22();
        let b = Matrix::identity(2);
        let mut bad = Matrix::zeros(3, 3);
        assert!(a.matmul_into(&b, &mut bad).is_err());
    }

    #[test]
    fn matmul_bitwise_matches_reference_across_sizes() {
        // Sizes straddle PAR_FLOP_THRESHOLD and GEMM_K_BLOCK so the
        // sequential, blocked, and multi-threaded paths are all
        // exercised; every one must agree with the reference kernel
        // bit-for-bit (not approximately).
        let mut rng = Rng::new(2);
        for (m, k, n) in [(3, 5, 4), (17, 70, 9), (80, 80, 80), (33, 130, 65)] {
            let a = Matrix::gaussian(m, k, &mut rng);
            let b = Matrix::gaussian(k, n, &mut rng);
            let got = a.matmul(&b).unwrap();
            let want = matmul_reference(&a, &b);
            assert_eq!(got.as_slice(), want.as_slice(), "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_into_reuses_buffer_and_overwrites() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(8, 6, &mut rng);
        let b = Matrix::gaussian(6, 7, &mut rng);
        let want = a.matmul(&b).unwrap();
        let mut out = Matrix::zeros(8, 7);
        for v in out.as_mut_slice() {
            *v = f64::NAN; // stale garbage must not leak through
        }
        a.matmul_into(&b, &mut out).unwrap();
        assert_eq!(out.as_slice(), want.as_slice());
    }

    #[test]
    fn gram_matches_explicit() {
        let mut rng = Rng::new(2);
        let x = Matrix::gaussian(10, 4, &mut rng);
        let g = x.gram();
        let g2 = x.transpose().matmul(&x).unwrap();
        for (a, b) in g.as_slice().iter().zip(g2.as_slice()) {
            assert!((a - b).abs() < 1e-10);
        }
        // symmetry
        for i in 0..4 {
            for j in 0..4 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_bitwise_matches_sequential_reference() {
        // 300*40*40 multiply-adds crosses PAR_FLOP_THRESHOLD, so this
        // runs the multi-threaded path on multi-core hosts. The data
        // problem's moment matrix comes from gram(); a bitwise change
        // here would shift every fixed-seed trajectory.
        let mut rng = Rng::new(4);
        let x = Matrix::gaussian(300, 40, &mut rng);
        let mut want = Matrix::zeros(40, 40);
        for i in 0..x.rows() {
            let row = x.row(i);
            for a in 0..40 {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                for b in 0..40 {
                    want[(a, b)] += ra * row[b];
                }
            }
        }
        let got = x.gram();
        assert_eq!(got.as_slice(), want.as_slice());
    }

    #[test]
    fn select_rows_cols() {
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ])
        .unwrap();
        let r = a.select_rows(&[2, 0]);
        assert_eq!(r, Matrix::from_rows(&[vec![7.0, 8.0, 9.0], vec![1.0, 2.0, 3.0]]).unwrap());
        let c = a.select_cols(&[1]);
        assert_eq!(c, Matrix::from_rows(&[vec![2.0], vec![5.0], vec![8.0]]).unwrap());
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(5, 8, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_blocked_matches_naive() {
        // Sizes around the tile edge: exact multiples, off-by-one, and
        // tall/wide extremes.
        let mut rng = Rng::new(5);
        for (r, c) in [(1, 1), (31, 33), (32, 32), (65, 7), (7, 65), (100, 3)] {
            let a = Matrix::gaussian(r, c, &mut rng);
            let t = a.transpose();
            assert_eq!(t.shape(), (c, r));
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t[(j, i)], a[(i, j)], "({r},{c}) at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn pad_to_preserves_and_zeros() {
        let a = m22();
        let p = a.pad_to(3, 4).unwrap();
        assert_eq!(p.shape(), (3, 4));
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(1, 1)], 4.0);
        assert_eq!(p[(2, 3)], 0.0);
        assert!(a.pad_to(1, 1).is_err());
    }

    #[test]
    fn vstack_works() {
        let a = m22();
        let b = Matrix::identity(2);
        let v = a.vstack(&b).unwrap();
        assert_eq!(v.shape(), (4, 2));
        assert_eq!(v.row(2), &[1.0, 0.0]);
    }

    #[test]
    fn overflowing_shapes_rejected_not_wrapped() {
        let huge = usize::MAX / 2;
        assert!(matches!(Matrix::try_zeros(huge, 4), Err(Error::Linalg(_))));
        // from_vec with a wrapping rows*cols must not accept a tiny
        // buffer as "matching".
        assert!(Matrix::from_vec(huge, 4, vec![0.0; 16]).is_err());
        assert!(Matrix::from_vec(usize::MAX, usize::MAX, Vec::new()).is_err());
        // Sane shapes still work.
        assert!(Matrix::try_zeros(3, 4).is_ok());
    }
}
