//! Row-major dense matrix over `f64`.

use crate::error::{Error, Result};
use crate::rng::Rng;

/// A dense row-major matrix of `f64`.
///
/// Deliberately minimal: the crate's numerics are dominated by mat-vec and
/// small dense solves, so we favour explicit loops (which LLVM vectorizes
/// well) over a BLAS dependency that is unavailable in this offline build.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Linalg(format!(
                "from_vec: {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        if rows.iter().any(|x| x.len() != c) {
            return Err(Error::Linalg("from_rows: ragged rows".into()));
        }
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            data.extend_from_slice(row);
        }
        Ok(Matrix { rows: r, cols: c, data })
    }

    /// Matrix with i.i.d. standard-normal entries.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: rng.gaussian_vec(rows * cols) }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Shape as `(rows, cols)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow the underlying row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutably borrow the underlying row-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the underlying buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Matrix transpose (new allocation).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, &v) in row.iter().enumerate() {
                t[(j, i)] = v;
            }
        }
        t
    }

    /// Mat-vec `self * x`, writing into `out` (len = rows).
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = super::ops::dot(self.row(i), x);
        }
    }

    /// Mat-vec `self * x` (allocates).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(x, &mut out);
        out
    }

    /// Transposed mat-vec `selfᵀ * x` (allocates; x has len = rows).
    /// Streams through rows so access stays contiguous.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for (o, &r) in out.iter_mut().zip(row.iter()) {
                *o += xi * r;
            }
        }
        out
    }

    /// Dense matrix product `self * other`.
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.rows {
            return Err(Error::Linalg(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Matrix::zeros(self.rows, other.cols);
        // ikj loop order: streams `other` rows, vectorizes the inner axpy.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        Ok(out)
    }

    /// Gram matrix `selfᵀ * self` (symmetric `cols x cols`).
    pub fn gram(&self) -> Matrix {
        let k = self.cols;
        let mut g = Matrix::zeros(k, k);
        for i in 0..self.rows {
            let row = self.row(i);
            for a in 0..k {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = g.row_mut(a);
                for (b, &rb) in row.iter().enumerate() {
                    grow[b] += ra * rb;
                }
            }
        }
        g
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Select a subset of columns into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (c, &j) in idx.iter().enumerate() {
                dst[c] = src[j];
            }
        }
        out
    }

    /// Vertically stack two matrices with equal column counts.
    pub fn vstack(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.cols {
            return Err(Error::Linalg("vstack: column mismatch".into()));
        }
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Frobenius norm.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Pad with zero rows/cols to the given shape (≥ current shape).
    pub fn pad_to(&self, rows: usize, cols: usize) -> Result<Matrix> {
        if rows < self.rows || cols < self.cols {
            return Err(Error::Linalg("pad_to: target smaller than source".into()));
        }
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        Ok(out)
    }

    /// Convert to `f32` row-major (for the PJRT/f32 artifact path).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m22() -> Matrix {
        Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap()
    }

    #[test]
    fn matvec_basic() {
        assert_eq!(m22().matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::gaussian(7, 5, &mut rng);
        let x = rng.gaussian_vec(7);
        let got = a.matvec_t(&x);
        let want = a.transpose().matvec(&x);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_identity() {
        let a = m22();
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i).unwrap(), a);
        assert_eq!(i.matmul(&a).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = m22();
        let b = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c, Matrix::from_rows(&[vec![2.0, 1.0], vec![4.0, 3.0]]).unwrap());
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = m22();
        let b = Matrix::zeros(3, 2);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn gram_matches_explicit() {
        let mut rng = Rng::new(2);
        let x = Matrix::gaussian(10, 4, &mut rng);
        let g = x.gram();
        let g2 = x.transpose().matmul(&x).unwrap();
        for (a, b) in g.as_slice().iter().zip(g2.as_slice()) {
            assert!((a - b).abs() < 1e-10);
        }
        // symmetry
        for i in 0..4 {
            for j in 0..4 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn select_rows_cols() {
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ])
        .unwrap();
        let r = a.select_rows(&[2, 0]);
        assert_eq!(r, Matrix::from_rows(&[vec![7.0, 8.0, 9.0], vec![1.0, 2.0, 3.0]]).unwrap());
        let c = a.select_cols(&[1]);
        assert_eq!(c, Matrix::from_rows(&[vec![2.0], vec![5.0], vec![8.0]]).unwrap());
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(5, 8, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn pad_to_preserves_and_zeros() {
        let a = m22();
        let p = a.pad_to(3, 4).unwrap();
        assert_eq!(p.shape(), (3, 4));
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(1, 1)], 4.0);
        assert_eq!(p[(2, 3)], 0.0);
        assert!(a.pad_to(1, 1).is_err());
    }

    #[test]
    fn vstack_works() {
        let a = m22();
        let b = Matrix::identity(2);
        let v = a.vstack(&b).unwrap();
        assert_eq!(v.shape(), (4, 2));
        assert_eq!(v.row(2), &[1.0, 0.0]);
    }
}
