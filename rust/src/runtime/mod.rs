//! Execution backends for worker compute.
//!
//! Workers evaluate one of two kernels per step — an encoded-shard
//! mat-vec (`rows · θ`) or a local least-squares gradient
//! (`Xᵀ(Xθ − y)`). The [`backend::ComputeBackend`] trait abstracts over:
//!
//! * [`backend::NativeBackend`] — straight Rust loops (no artifacts
//!   required; the default for tests and CI).
//! * [`pjrt::PjrtBackend`] — the three-layer path: loads the HLO-text
//!   artifacts AOT-compiled from the JAX/Pallas model
//!   (`python/compile/aot.py`), compiles them on the PJRT CPU client via
//!   the `xla` crate, and executes them on the worker hot path. Python is
//!   never invoked at runtime.

pub mod artifact;
pub mod backend;
pub mod pjrt;
pub mod xla_stub;

pub use backend::{BackendChoice, ComputeBackend, NativeBackend};
