//! Offline stand-in for the `xla` crate's PJRT surface.
//!
//! The offline crate set does not ship the `xla` crate, so this module
//! mirrors exactly the API slice `runtime::pjrt` consumes. Every
//! entry point that would touch a real PJRT client returns
//! [`Error::Unavailable`]; `PjrtBackend::load` therefore fails loudly
//! (and `cargo test` skips the PJRT integration suite) instead of the
//! whole crate failing to build. Building against real XLA is a
//! one-line swap: replace the `use crate::runtime::xla_stub as xla;`
//! alias in `pjrt.rs` with the real crate.

#![allow(dead_code)]

use std::fmt;

/// Error type mirroring `xla::Error`.
#[derive(Debug)]
pub enum Error {
    /// PJRT is not available in this build.
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla/pjrt support is not compiled into this binary (offline stub)")
    }
}

impl std::error::Error for Error {}

/// Result alias mirroring the real crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Stand-in for `xla::PjRtClient`.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Mirrors `xla::PjRtClient::cpu`; always unavailable offline.
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable)
    }

    /// Mirrors `compile`; unreachable offline (no client can exist).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }

    /// Mirrors `buffer_from_host_buffer`; unreachable offline.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable)
    }
}

/// Stand-in for `xla::PjRtBuffer` (a device-resident array).
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Mirrors `to_literal_sync`.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors `execute` (literal arguments).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }

    /// Mirrors `execute_b` (buffer arguments).
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// Stand-in for `xla::Literal` (a host-resident array).
#[derive(Debug)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Mirrors `Literal::vec1`.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    /// Mirrors `reshape`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    /// Mirrors `to_tuple1`.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    /// Mirrors `to_vec`.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}

/// Stand-in for `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Mirrors `from_text_file`.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable)
    }
}

/// Stand-in for `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Mirrors `from_proto`.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_unavailable_offline() {
        let err = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(format!("{err}").contains("offline stub"));
    }

    #[test]
    fn literal_ops_fail_loud() {
        let l = Literal::vec1(&[1.0f32]);
        assert!(l.reshape(&[1]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
