//! AOT artifact registry.
//!
//! `python/compile/aot.py` lowers the L2 JAX model (which calls the L1
//! Pallas kernel) to HLO **text** once per (kernel, shape) pair:
//!
//! * `shard_matvec_{R}x{C}.hlo.txt` — `(rows f32[R,C], theta f32[C]) ->
//!   f32[R]`, the Scheme 1/2 worker task;
//! * `local_grad_{R}x{C}.hlo.txt` — `(x f32[R,C], y f32[R], theta
//!   f32[C]) -> f32[C]`, the KSDY17/uncoded worker task.
//!
//! Shapes are fixed at AOT time, so the registry picks, for a runtime
//! shard of shape `(r, c)`, the smallest artifact with `R ≥ r` and
//! `C ≥ c`; inputs are zero-padded (zero rows/columns contribute nothing
//! to either kernel, so padding is exact).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Which AOT kernel an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Kernel {
    /// `rows · θ`.
    ShardMatvec,
    /// `Xᵀ(Xθ − y)`.
    LocalGrad,
}

impl Kernel {
    /// File-name prefix.
    pub fn prefix(self) -> &'static str {
        match self {
            Kernel::ShardMatvec => "shard_matvec",
            Kernel::LocalGrad => "local_grad",
        }
    }
}

/// A discovered artifact file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    /// Kernel kind.
    pub kernel: Kernel,
    /// Compiled row count `R`.
    pub rows: usize,
    /// Compiled column count `C`.
    pub cols: usize,
    /// File path.
    pub path: PathBuf,
}

/// Registry of artifacts found in a directory.
#[derive(Debug, Clone, Default)]
pub struct ArtifactRegistry {
    by_kernel: BTreeMap<Kernel, Vec<Artifact>>,
}

/// Parse `prefix_{R}x{C}.hlo.txt`.
fn parse_name(name: &str) -> Option<(Kernel, usize, usize)> {
    let stem = name.strip_suffix(".hlo.txt")?;
    for kernel in [Kernel::ShardMatvec, Kernel::LocalGrad] {
        if let Some(shape) = stem.strip_prefix(kernel.prefix()) {
            let shape = shape.strip_prefix('_')?;
            let (r, c) = shape.split_once('x')?;
            return Some((kernel, r.parse().ok()?, c.parse().ok()?));
        }
    }
    None
}

impl ArtifactRegistry {
    /// Scan a directory for artifacts. An empty registry is returned for
    /// an empty/missing directory (callers decide whether that is fatal).
    pub fn scan(dir: &Path) -> Result<Self> {
        let mut by_kernel: BTreeMap<Kernel, Vec<Artifact>> = BTreeMap::new();
        if !dir.exists() {
            return Ok(ArtifactRegistry { by_kernel });
        }
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some((kernel, rows, cols)) = parse_name(&name) {
                by_kernel.entry(kernel).or_default().push(Artifact {
                    kernel,
                    rows,
                    cols,
                    path: entry.path(),
                });
            }
        }
        // Sort by padded area so `find` takes the first (smallest) fit.
        for v in by_kernel.values_mut() {
            v.sort_by_key(|a| (a.rows * a.cols, a.rows, a.cols));
        }
        Ok(ArtifactRegistry { by_kernel })
    }

    /// Total artifacts known.
    pub fn len(&self) -> usize {
        self.by_kernel.values().map(|v| v.len()).sum()
    }

    /// Is the registry empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All artifacts of a kernel (sorted by area).
    pub fn all(&self, kernel: Kernel) -> &[Artifact] {
        self.by_kernel.get(&kernel).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Smallest artifact covering shape `(rows, cols)`.
    pub fn find(&self, kernel: Kernel, rows: usize, cols: usize) -> Result<&Artifact> {
        self.all(kernel)
            .iter()
            .find(|a| a.rows >= rows && a.cols >= cols)
            .ok_or_else(|| {
                Error::Pjrt(format!(
                    "no {} artifact covers shape ({rows}, {cols}); run `make artifacts` \
                     (available: {:?})",
                    kernel.prefix(),
                    self.all(kernel)
                        .iter()
                        .map(|a| (a.rows, a.cols))
                        .collect::<Vec<_>>()
                ))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_parsing() {
        assert_eq!(
            parse_name("shard_matvec_64x1024.hlo.txt"),
            Some((Kernel::ShardMatvec, 64, 1024))
        );
        assert_eq!(
            parse_name("local_grad_128x256.hlo.txt"),
            Some((Kernel::LocalGrad, 128, 256))
        );
        assert_eq!(parse_name("other_64x64.hlo.txt"), None);
        assert_eq!(parse_name("shard_matvec_64.hlo.txt"), None);
        assert_eq!(parse_name("shard_matvec_64x64.txt"), None);
    }

    #[test]
    fn scan_and_find() {
        let dir = crate::testing::TempDir::new("t").unwrap();
        for name in [
            "shard_matvec_16x32.hlo.txt",
            "shard_matvec_64x128.hlo.txt",
            "shard_matvec_256x512.hlo.txt",
            "local_grad_64x64.hlo.txt",
            "README.md",
        ] {
            std::fs::write(dir.path().join(name), "dummy").unwrap();
        }
        let reg = ArtifactRegistry::scan(dir.path()).unwrap();
        assert_eq!(reg.len(), 4);
        // Exact fit.
        let a = reg.find(Kernel::ShardMatvec, 16, 32).unwrap();
        assert_eq!((a.rows, a.cols), (16, 32));
        // Smallest cover.
        let a = reg.find(Kernel::ShardMatvec, 17, 32).unwrap();
        assert_eq!((a.rows, a.cols), (64, 128));
        let a = reg.find(Kernel::ShardMatvec, 65, 500).unwrap();
        assert_eq!((a.rows, a.cols), (256, 512));
        // Too big.
        assert!(reg.find(Kernel::ShardMatvec, 1000, 1).is_err());
        // Kernel separation.
        assert!(reg.find(Kernel::LocalGrad, 64, 65).is_err());
    }

    #[test]
    fn missing_dir_is_empty() {
        let reg = ArtifactRegistry::scan(Path::new("/nonexistent/path/xyz")).unwrap();
        assert!(reg.is_empty());
    }
}
