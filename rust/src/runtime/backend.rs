//! The worker-compute abstraction and its native implementation.

use crate::error::Result;
use crate::linalg::Matrix;

/// Which backend to instantiate (CLI / config selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// Pure-Rust loops; always available.
    Native,
    /// AOT-compiled XLA executables via PJRT (requires `make artifacts`).
    Pjrt,
}

impl std::str::FromStr for BackendChoice {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "native" => Ok(BackendChoice::Native),
            "pjrt" => Ok(BackendChoice::Pjrt),
            other => Err(format!("unknown backend '{other}' (expected native|pjrt)")),
        }
    }
}

/// Worker-side compute kernels.
///
/// Implementations must be shareable across worker threads.
pub trait ComputeBackend: Send + Sync {
    /// Dense mat-vec `rows · θ` — the Scheme 1/2 worker task.
    fn matvec(&self, rows: &Matrix, theta: &[f64]) -> Result<Vec<f64>>;

    /// Local least-squares gradient `Xᵀ(Xθ − y)` — the KSDY17 / uncoded /
    /// replication worker task.
    fn local_grad(&self, x: &Matrix, y: &[f64], theta: &[f64]) -> Result<Vec<f64>> {
        let mut r = self.matvec(x, theta)?;
        for (ri, yi) in r.iter_mut().zip(y) {
            *ri -= yi;
        }
        Ok(x.matvec_t(&r))
    }

    /// Keyed variant of [`ComputeBackend::matvec`]: `key` identifies a
    /// matrix that is *constant across calls* (a worker's encoded shard),
    /// letting backends cache device-resident copies. The default ignores
    /// the key.
    fn matvec_keyed(&self, _key: Option<u64>, rows: &Matrix, theta: &[f64]) -> Result<Vec<f64>> {
        self.matvec(rows, theta)
    }

    /// Keyed variant of [`ComputeBackend::local_grad`] (same contract:
    /// `x` and `y` are constant for a given key).
    fn local_grad_keyed(
        &self,
        _key: Option<u64>,
        x: &Matrix,
        y: &[f64],
        theta: &[f64],
    ) -> Result<Vec<f64>> {
        self.local_grad(x, y, theta)
    }

    /// Buffer-reusing variant of [`ComputeBackend::matvec_keyed`]: the
    /// result is written into `out` (resized to `rows.rows()`), so a
    /// worker that hands back the same buffer every step allocates
    /// nothing. The default moves the allocating path's result into
    /// `out`; backends with native in-place kernels override it.
    fn matvec_keyed_into(
        &self,
        key: Option<u64>,
        rows: &Matrix,
        theta: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<()> {
        *out = self.matvec_keyed(key, rows, theta)?;
        Ok(())
    }

    /// Buffer-reusing variant of [`ComputeBackend::local_grad_keyed`]
    /// (result length `theta.len()`).
    fn local_grad_keyed_into(
        &self,
        key: Option<u64>,
        x: &Matrix,
        y: &[f64],
        theta: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<()> {
        *out = self.local_grad_keyed(key, x, y, theta)?;
        Ok(())
    }

    /// Human-readable backend name (metrics / logs).
    fn name(&self) -> &'static str;
}

/// Pure-Rust backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn matvec(&self, rows: &Matrix, theta: &[f64]) -> Result<Vec<f64>> {
        Ok(rows.matvec(theta))
    }

    fn matvec_keyed_into(
        &self,
        _key: Option<u64>,
        rows: &Matrix,
        theta: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<()> {
        // The zero-allocation worker fast path: every output element is
        // overwritten, so a recycled buffer needs no clearing.
        out.resize(rows.rows(), 0.0);
        rows.matvec_into(theta, out);
        Ok(())
    }

    fn local_grad_keyed_into(
        &self,
        _key: Option<u64>,
        x: &Matrix,
        y: &[f64],
        theta: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<()> {
        // The residual `Xθ − y` is inherently a fresh length-m vector
        // here (stateless backend); only the k-length output reuses the
        // caller's buffer. Matches local_grad()'s arithmetic exactly.
        let mut r = x.matvec(theta);
        for (ri, yi) in r.iter_mut().zip(y) {
            *ri -= yi;
        }
        out.resize(x.cols(), 0.0);
        x.matvec_t_into(&r, out);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn native_matvec() {
        let b = NativeBackend;
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(b.matvec(&m, &[1.0, 1.0]).unwrap(), vec![3.0, 7.0]);
    }

    #[test]
    fn default_local_grad_matches_formula() {
        let b = NativeBackend;
        let mut rng = Rng::new(1);
        let x = Matrix::gaussian(12, 5, &mut rng);
        let y = rng.gaussian_vec(12);
        let theta = rng.gaussian_vec(5);
        let got = b.local_grad(&x, &y, &theta).unwrap();
        // Explicit: Xᵀ X θ − Xᵀ y.
        let want = {
            let mut g = x.gram().matvec(&theta);
            let xty = x.matvec_t(&y);
            for (gi, bi) in g.iter_mut().zip(&xty) {
                *gi -= bi;
            }
            g
        };
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn into_variants_match_allocating_paths_bitwise() {
        let b = NativeBackend;
        let mut rng = Rng::new(2);
        let m = Matrix::gaussian(9, 4, &mut rng);
        let theta = rng.gaussian_vec(4);
        let mut out = vec![f64::NAN; 1]; // wrong-size stale buffer
        b.matvec_keyed_into(Some(1), &m, &theta, &mut out).unwrap();
        assert_eq!(out, b.matvec(&m, &theta).unwrap());

        let y = rng.gaussian_vec(9);
        let mut g = Vec::new();
        b.local_grad_keyed_into(None, &m, &y, &theta, &mut g).unwrap();
        assert_eq!(g, b.local_grad(&m, &y, &theta).unwrap());
    }

    #[test]
    fn backend_choice_parses() {
        use std::str::FromStr;
        assert_eq!(BackendChoice::from_str("native").unwrap(), BackendChoice::Native);
        assert_eq!(BackendChoice::from_str("pjrt").unwrap(), BackendChoice::Pjrt);
        assert!(BackendChoice::from_str("gpu").is_err());
    }
}
