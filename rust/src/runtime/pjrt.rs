//! PJRT execution backend — the L3 end of the three-layer architecture.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (L2 JAX model wrapping the L1 Pallas kernel), compiles each once on
//! the PJRT CPU client (`xla` crate), and serves worker compute requests
//! from the compiled executables. HLO *text* is the interchange format:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! ## Threading
//!
//! The `xla` crate's wrapper types hold raw pointers and are neither
//! `Send` nor `Sync`. PJRT CPU execution is internally thread-safe and
//! runs its own intra-op thread pool, so we serialize *dispatch* behind
//! one mutex and mark the guarded state `Send`. Worker threads therefore
//! queue on the lock; the XLA runtime still parallelizes each kernel.
//! (Per-worker `compute_ns` then includes lock wait — acceptable for the
//! simulated-time metric, and called out in EXPERIMENTS.md §Perf.)

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::linalg::Matrix;
// Offline builds compile against the in-tree API stub; swap this alias
// for the real `xla` crate to enable actual PJRT execution.
use crate::runtime::xla_stub as xla;

use super::artifact::{ArtifactRegistry, Kernel};
use super::backend::ComputeBackend;

/// A device-resident copy of a worker's constant payload data, padded to
/// the artifact shape it is executed with.
enum CachedPayload {
    /// Shard matrix buffer (padded `R x C`).
    Mat { rows: usize, cols: usize, buf: xla::PjRtBuffer },
    /// Data-block buffers: `x` (padded `R x C`) and `y` (padded `R`).
    Xy { rows: usize, cols: usize, x: xla::PjRtBuffer, y: xla::PjRtBuffer },
}

/// Everything that lives behind the dispatch lock.
struct PjrtInner {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    /// Compiled executables, keyed by (kernel, R, C).
    executables: HashMap<(Kernel, usize, usize), xla::PjRtLoadedExecutable>,
    /// Device-resident payload copies, keyed by the caller-supplied
    /// payload identity (worker id). §Perf: uploading the shard once
    /// instead of per step removes the dominant per-call cost.
    payload_cache: HashMap<(Kernel, u64), CachedPayload>,
    /// Scratch buffers for padding (reused across calls).
    mat_scratch: Vec<f32>,
    vec_scratch: Vec<f32>,
    aux_scratch: Vec<f32>,
}

// SAFETY: `PjrtInner` is only ever accessed through the `Mutex` in
// `PjrtBackend`, i.e. by at most one thread at a time; the underlying
// PJRT CPU client additionally documents thread-safe execution. Moving
// the raw-pointer wrappers between threads under that discipline is
// sound.
unsafe impl Send for PjrtInner {}

/// The PJRT compute backend.
pub struct PjrtBackend {
    inner: Mutex<PjrtInner>,
}

impl PjrtBackend {
    /// Create a CPU PJRT client and scan `dir` for artifacts. Fails if no
    /// artifacts are present (run `make artifacts`).
    pub fn load(dir: &Path) -> Result<Self> {
        let registry = ArtifactRegistry::scan(dir)?;
        if registry.is_empty() {
            return Err(Error::Pjrt(format!(
                "no artifacts found in {} — run `make artifacts`",
                dir.display()
            )));
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtBackend {
            inner: Mutex::new(PjrtInner {
                client,
                registry,
                executables: HashMap::new(),
                payload_cache: HashMap::new(),
                mat_scratch: Vec::new(),
                vec_scratch: Vec::new(),
                aux_scratch: Vec::new(),
            }),
        })
    }

    /// Artifact count (diagnostics).
    pub fn artifact_count(&self) -> usize {
        self.inner.lock().unwrap().registry.len()
    }
}

impl PjrtInner {
    /// Get or compile the executable for (kernel, R, C); returns the key.
    fn ensure_compiled(
        &mut self,
        kernel: Kernel,
        rows: usize,
        cols: usize,
    ) -> Result<(Kernel, usize, usize)> {
        let art = self.registry.find(kernel, rows, cols)?;
        let key = (kernel, art.rows, art.cols);
        if !self.executables.contains_key(&key) {
            let proto = xla::HloModuleProto::from_text_file(
                art.path
                    .to_str()
                    .ok_or_else(|| Error::Pjrt("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.executables.insert(key, exe);
        }
        Ok(key)
    }

    /// Zero-pad `mat` (row-major f64, shape r x c) into the f32 scratch at
    /// shape R x C.
    fn pad_matrix(&mut self, mat: &Matrix, big_r: usize, big_c: usize) {
        let (r, c) = mat.shape();
        self.mat_scratch.clear();
        self.mat_scratch.resize(big_r * big_c, 0.0);
        for i in 0..r {
            let src = mat.row(i);
            let dst = &mut self.mat_scratch[i * big_c..i * big_c + c];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = s as f32;
            }
        }
    }

    fn run(
        &mut self,
        key: (Kernel, usize, usize),
        inputs: &[xla::Literal],
    ) -> Result<Vec<f32>> {
        let exe = self.executables.get(&key).expect("compiled above");
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Pad `theta` and upload as a device buffer.
    fn theta_buffer(&mut self, theta: &[f64], big_c: usize) -> Result<xla::PjRtBuffer> {
        self.vec_scratch.clear();
        self.vec_scratch.resize(big_c, 0.0);
        for (d, &s) in self.vec_scratch.iter_mut().zip(theta) {
            *d = s as f32;
        }
        Ok(self.client.buffer_from_host_buffer::<f32>(&self.vec_scratch, &[big_c], None)?)
    }

    /// Get or upload the cached shard-matrix buffer for `key`.
    fn cached_mat(
        &mut self,
        cache_key: u64,
        mat: &Matrix,
        big_r: usize,
        big_c: usize,
    ) -> Result<()> {
        let full_key = (Kernel::ShardMatvec, cache_key);
        let hit = matches!(
            self.payload_cache.get(&full_key),
            Some(CachedPayload::Mat { rows, cols, .. }) if *rows == big_r && *cols == big_c
        );
        if !hit {
            self.pad_matrix(mat, big_r, big_c);
            let buf = self.client.buffer_from_host_buffer::<f32>(
                &self.mat_scratch,
                &[big_r, big_c],
                None,
            )?;
            self.payload_cache
                .insert(full_key, CachedPayload::Mat { rows: big_r, cols: big_c, buf });
        }
        Ok(())
    }

    /// Get or upload the cached (x, y) buffers for `key`.
    fn cached_xy(
        &mut self,
        cache_key: u64,
        x: &Matrix,
        y: &[f64],
        big_r: usize,
        big_c: usize,
    ) -> Result<()> {
        let full_key = (Kernel::LocalGrad, cache_key);
        let hit = matches!(
            self.payload_cache.get(&full_key),
            Some(CachedPayload::Xy { rows, cols, .. }) if *rows == big_r && *cols == big_c
        );
        if !hit {
            self.pad_matrix(x, big_r, big_c);
            let xb = self.client.buffer_from_host_buffer::<f32>(
                &self.mat_scratch,
                &[big_r, big_c],
                None,
            )?;
            self.aux_scratch.clear();
            self.aux_scratch.resize(big_r, 0.0);
            for (d, &s) in self.aux_scratch.iter_mut().zip(y) {
                *d = s as f32;
            }
            let yb = self.client.buffer_from_host_buffer::<f32>(
                &self.aux_scratch,
                &[big_r],
                None,
            )?;
            self.payload_cache.insert(
                full_key,
                CachedPayload::Xy { rows: big_r, cols: big_c, x: xb, y: yb },
            );
        }
        Ok(())
    }
}

/// Buffer-argument execution (cached-payload fast path).
fn run_exe_b(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[&xla::PjRtBuffer],
) -> Result<Vec<f32>> {
    let result = exe.execute_b::<&xla::PjRtBuffer>(inputs)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = result.to_tuple1()?;
    Ok(out.to_vec::<f32>()?)
}

impl ComputeBackend for PjrtBackend {
    fn matvec(&self, rows: &Matrix, theta: &[f64]) -> Result<Vec<f64>> {
        let (r, c) = rows.shape();
        if theta.len() != c {
            return Err(Error::Pjrt("matvec: theta length mismatch".into()));
        }
        let mut inner = self.inner.lock().unwrap();
        let key = inner.ensure_compiled(Kernel::ShardMatvec, r, c)?;
        let (_, big_r, big_c) = key;
        inner.pad_matrix(rows, big_r, big_c);
        inner.vec_scratch.clear();
        inner.vec_scratch.resize(big_c, 0.0);
        for (d, &s) in inner.vec_scratch.iter_mut().zip(theta) {
            *d = s as f32;
        }
        let mat_lit = xla::Literal::vec1(&inner.mat_scratch)
            .reshape(&[big_r as i64, big_c as i64])?;
        let vec_lit = xla::Literal::vec1(&inner.vec_scratch);
        let out = inner.run(key, &[mat_lit, vec_lit])?;
        Ok(out[..r].iter().map(|&v| v as f64).collect())
    }

    fn matvec_keyed(&self, key: Option<u64>, rows: &Matrix, theta: &[f64]) -> Result<Vec<f64>> {
        let Some(cache_key) = key else { return self.matvec(rows, theta) };
        let (r, c) = rows.shape();
        if theta.len() != c {
            return Err(Error::Pjrt("matvec: theta length mismatch".into()));
        }
        let mut inner = self.inner.lock().unwrap();
        let exe_key = inner.ensure_compiled(Kernel::ShardMatvec, r, c)?;
        let (_, big_r, big_c) = exe_key;
        inner.cached_mat(cache_key, rows, big_r, big_c)?;
        let theta_buf = inner.theta_buffer(theta, big_c)?;
        // Immutable phase: fetch executable + cached shard, execute.
        let inner = &*inner;
        let exe = inner.executables.get(&exe_key).expect("compiled above");
        let mat_buf = match inner.payload_cache.get(&(Kernel::ShardMatvec, cache_key)) {
            Some(CachedPayload::Mat { buf, .. }) => buf,
            _ => unreachable!("cached above"),
        };
        let out = run_exe_b(exe, &[mat_buf, &theta_buf])?;
        Ok(out[..r].iter().map(|&v| v as f64).collect())
    }

    fn local_grad_keyed(
        &self,
        key: Option<u64>,
        x: &Matrix,
        y: &[f64],
        theta: &[f64],
    ) -> Result<Vec<f64>> {
        let Some(cache_key) = key else { return self.local_grad(x, y, theta) };
        let (r, c) = x.shape();
        if y.len() != r || theta.len() != c {
            return Err(Error::Pjrt("local_grad: shape mismatch".into()));
        }
        let mut inner = self.inner.lock().unwrap();
        let exe_key = inner.ensure_compiled(Kernel::LocalGrad, r, c)?;
        let (_, big_r, big_c) = exe_key;
        inner.cached_xy(cache_key, x, y, big_r, big_c)?;
        let theta_buf = inner.theta_buffer(theta, big_c)?;
        let inner = &*inner;
        let exe = inner.executables.get(&exe_key).expect("compiled above");
        let (x_buf, y_buf) = match inner.payload_cache.get(&(Kernel::LocalGrad, cache_key)) {
            Some(CachedPayload::Xy { x, y, .. }) => (x, y),
            _ => unreachable!("cached above"),
        };
        let out = run_exe_b(exe, &[x_buf, y_buf, &theta_buf])?;
        Ok(out[..c].iter().map(|&v| v as f64).collect())
    }

    fn local_grad(&self, x: &Matrix, y: &[f64], theta: &[f64]) -> Result<Vec<f64>> {
        let (r, c) = x.shape();
        if y.len() != r || theta.len() != c {
            return Err(Error::Pjrt("local_grad: shape mismatch".into()));
        }
        let mut inner = self.inner.lock().unwrap();
        let key = inner.ensure_compiled(Kernel::LocalGrad, r, c)?;
        let (_, big_r, big_c) = key;
        inner.pad_matrix(x, big_r, big_c);
        inner.vec_scratch.clear();
        inner.vec_scratch.resize(big_c, 0.0);
        for (d, &s) in inner.vec_scratch.iter_mut().zip(theta) {
            *d = s as f32;
        }
        inner.aux_scratch.clear();
        inner.aux_scratch.resize(big_r, 0.0);
        for (d, &s) in inner.aux_scratch.iter_mut().zip(y) {
            *d = s as f32;
        }
        let x_lit = xla::Literal::vec1(&inner.mat_scratch)
            .reshape(&[big_r as i64, big_c as i64])?;
        let y_lit = xla::Literal::vec1(&inner.aux_scratch);
        let t_lit = xla::Literal::vec1(&inner.vec_scratch);
        let out = inner.run(key, &[x_lit, y_lit, t_lit])?;
        Ok(out[..c].iter().map(|&v| v as f64).collect())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Execution tests live in rust/tests/integration_pjrt.rs (they need
    // `make artifacts` first). Here we only test failure handling.

    #[test]
    fn missing_artifacts_dir_fails_loud() {
        let err = match PjrtBackend::load(Path::new("/nonexistent/zzz")) {
            Ok(_) => panic!("expected failure"),
            Err(e) => e,
        };
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn empty_dir_fails_loud() {
        let dir = crate::testing::TempDir::new("t").unwrap();
        assert!(PjrtBackend::load(dir.path()).is_err());
    }
}
