//! Stopping rules for the optimization loops.
//!
//! The paper's §4 metric is "the number of steps until the Euclidean
//! distance of the evaluated parameter from the actual parameter vector
//! θ* is within a small threshold"; [`ConvergenceRule::DistanceToTruth`]
//! implements exactly that. The other rules support the unconstrained
//! library use cases where θ* is unknown.

/// Why an optimization loop stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The convergence rule fired at this step.
    Converged,
    /// The step budget was exhausted.
    MaxSteps,
    /// The iterate diverged (non-finite values).
    Diverged,
}

/// A stopping rule evaluated once per optimization step.
#[derive(Debug, Clone)]
pub enum ConvergenceRule {
    /// `‖θ_t − θ*‖₂ ≤ tol` (the paper's criterion).
    DistanceToTruth { theta_star: Vec<f64>, tol: f64 },
    /// `‖θ_t − θ*‖₂ / max(‖θ*‖, 1) ≤ tol`.
    RelativeDistance { theta_star: Vec<f64>, tol: f64 },
    /// `‖∇L(θ_t)‖₂ ≤ tol` (needs the caller to pass the gradient).
    GradientNorm { tol: f64 },
    /// Never stop early (run exactly `max_steps`).
    Never,
}

impl ConvergenceRule {
    /// Evaluate the rule. `grad` may be `None` for rules that do not need
    /// it (GradientNorm returns `false` in that case).
    pub fn is_converged(&self, theta: &[f64], grad: Option<&[f64]>) -> bool {
        match self {
            ConvergenceRule::DistanceToTruth { theta_star, tol } => {
                crate::linalg::dist2(theta, theta_star) <= *tol
            }
            ConvergenceRule::RelativeDistance { theta_star, tol } => {
                let d = crate::linalg::dist2(theta, theta_star);
                let n = crate::linalg::norm2(theta_star).max(1.0);
                d / n <= *tol
            }
            ConvergenceRule::GradientNorm { tol } => {
                grad.map(|g| crate::linalg::norm2(g) <= *tol).unwrap_or(false)
            }
            ConvergenceRule::Never => false,
        }
    }

    /// Detect divergence: any non-finite coordinate.
    pub fn is_diverged(theta: &[f64]) -> bool {
        theta.iter().any(|v| !v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_rule() {
        let rule = ConvergenceRule::DistanceToTruth { theta_star: vec![1.0, 1.0], tol: 0.1 };
        assert!(rule.is_converged(&[1.0, 1.05], None));
        assert!(!rule.is_converged(&[0.0, 0.0], None));
    }

    #[test]
    fn relative_rule_scales() {
        let rule =
            ConvergenceRule::RelativeDistance { theta_star: vec![10.0, 0.0], tol: 0.01 };
        assert!(rule.is_converged(&[10.05, 0.0], None));
        assert!(!rule.is_converged(&[9.0, 0.0], None));
    }

    #[test]
    fn gradient_rule_requires_grad() {
        let rule = ConvergenceRule::GradientNorm { tol: 0.1 };
        assert!(!rule.is_converged(&[0.0], None));
        assert!(rule.is_converged(&[0.0], Some(&[0.05])));
        assert!(!rule.is_converged(&[0.0], Some(&[0.5])));
    }

    #[test]
    fn never_never_stops() {
        assert!(!ConvergenceRule::Never.is_converged(&[0.0], Some(&[0.0])));
    }

    #[test]
    fn divergence_detection() {
        assert!(ConvergenceRule::is_diverged(&[1.0, f64::NAN]));
        assert!(ConvergenceRule::is_diverged(&[f64::INFINITY]));
        assert!(!ConvergenceRule::is_diverged(&[1.0, -2.0]));
    }
}
