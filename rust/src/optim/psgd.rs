//! Projected *stochastic* gradient descent (eq. 13) — the comparator that
//! Scheme 2 is proven equivalent to (in expectation) under Assumption 1.
//!
//! At step `t` a uniformly random sample `i` is drawn and
//! `θ_t = P_Θ(θ_{t-1} − η·m·(x_i x_iᵀ θ_{t-1} − y_i x_i))`;
//! `m·(x_i x_iᵀθ − y_i x_i)` is an unbiased estimate of `∇L(θ)`.

use super::convergence::{ConvergenceRule, StopReason};
use super::pgd::Trace;
use super::projections::Projection;
use crate::data::RegressionProblem;
use crate::rng::Rng;

/// Options for the PSGD loop.
#[derive(Debug, Clone)]
pub struct PsgdOptions {
    /// Step size `η` (`None` = spectral `1/λ_max(M)`; note PSGD usually
    /// needs a smaller step than PGD — pass an explicit value for the
    /// theory-matched `R/(B√T)` schedule).
    pub step_size: Option<f64>,
    /// Projection `P_Θ`.
    pub projection: Projection,
    /// Stop rule (evaluated on the running iterate).
    pub rule: ConvergenceRule,
    /// Hard cap on steps.
    pub max_steps: usize,
    /// Mini-batch size (1 = the paper's single-sample estimator).
    pub batch: usize,
    /// RNG seed for the sample draws.
    pub seed: u64,
}

impl Default for PsgdOptions {
    fn default() -> Self {
        PsgdOptions {
            step_size: None,
            projection: Projection::None,
            rule: ConvergenceRule::Never,
            max_steps: 1000,
            batch: 1,
            seed: 0,
        }
    }
}

/// Run PSGD on a regression problem.
pub fn psgd(problem: &RegressionProblem, opts: &PsgdOptions) -> Trace {
    let k = problem.k();
    let m = problem.m();
    let eta = opts.step_size.unwrap_or_else(|| problem.spectral_step_size());
    let mut rng = Rng::new(opts.seed);
    let mut theta = vec![0.0; k];
    let mut grad = vec![0.0; k];

    for t in 1..=opts.max_steps {
        grad.iter_mut().for_each(|g| *g = 0.0);
        // Unbiased estimator: (m / batch) Σ_{i in batch} (x_i x_iᵀθ − y_i x_i).
        for _ in 0..opts.batch {
            let i = rng.below(m);
            let xi = problem.x.row(i);
            let pred = crate::linalg::dot(xi, &theta);
            let coef = (m as f64 / opts.batch as f64) * (pred - problem.y[i]);
            crate::linalg::axpy(coef, xi, &mut grad);
        }
        for (th, g) in theta.iter_mut().zip(&grad) {
            *th -= eta * g;
        }
        opts.projection.apply(&mut theta);

        if ConvergenceRule::is_diverged(&theta) {
            return Trace { theta, steps: t, stop: StopReason::Diverged, samples: vec![] };
        }
        if opts.rule.is_converged(&theta, Some(&grad)) {
            return Trace { theta, steps: t, stop: StopReason::Converged, samples: vec![] };
        }
    }
    Trace { theta, steps: opts.max_steps, stop: StopReason::MaxSteps, samples: vec![] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthConfig;

    #[test]
    fn stochastic_gradient_is_unbiased() {
        let p = RegressionProblem::generate(&SynthConfig::dense(50, 6), 1);
        let mut rng = Rng::new(2);
        let theta = rng.gaussian_vec(6);
        let exact = p.gradient(&theta);
        // Average the single-sample estimator over all m samples exactly.
        let mut avg = vec![0.0; 6];
        for i in 0..50 {
            let xi = p.x.row(i);
            let coef = 50.0 * (crate::linalg::dot(xi, &theta) - p.y[i]);
            crate::linalg::axpy(coef / 50.0, xi, &mut avg);
        }
        for (a, e) in avg.iter().zip(&exact) {
            assert!((a - e).abs() < 1e-8, "{a} vs {e}");
        }
    }

    #[test]
    fn converges_with_decaying_accuracy() {
        // With the conservative spectral step divided by m-scaling, PSGD
        // approaches θ* (noiseless problem ⇒ the noise vanishes at θ*, so
        // constant-step SGD converges exactly).
        let p = RegressionProblem::generate(&SynthConfig::dense(200, 10), 3);
        let eta = p.spectral_step_size() / 10.0;
        let opts = PsgdOptions {
            step_size: Some(eta),
            rule: ConvergenceRule::RelativeDistance {
                theta_star: p.theta_star.clone(),
                tol: 1e-3,
            },
            max_steps: 200_000,
            seed: 4,
            ..Default::default()
        };
        let tr = psgd(&p, &opts);
        assert_eq!(tr.stop, StopReason::Converged, "error after {} steps", tr.steps);
    }

    #[test]
    fn batching_reduces_steps() {
        let p = RegressionProblem::generate(&SynthConfig::dense(200, 10), 5);
        let eta = p.spectral_step_size() / 10.0;
        let rule = ConvergenceRule::RelativeDistance {
            theta_star: p.theta_star.clone(),
            tol: 1e-3,
        };
        let b1 = psgd(
            &p,
            &PsgdOptions {
                step_size: Some(eta),
                rule: rule.clone(),
                max_steps: 500_000,
                batch: 1,
                seed: 6,
                ..Default::default()
            },
        );
        let b16 = psgd(
            &p,
            &PsgdOptions {
                step_size: Some(eta),
                rule,
                max_steps: 500_000,
                batch: 16,
                seed: 6,
                ..Default::default()
            },
        );
        assert!(
            b16.steps < b1.steps,
            "batch16 {} steps !< batch1 {} steps",
            b16.steps,
            b1.steps
        );
    }

    #[test]
    fn deterministic_in_seed() {
        let p = RegressionProblem::generate(&SynthConfig::dense(64, 8), 7);
        let opts = PsgdOptions { max_steps: 100, seed: 9, ..Default::default() };
        let a = psgd(&p, &opts);
        let b = psgd(&p, &opts);
        assert_eq!(a.theta, b.theta);
    }
}
