//! Projection operators `P_Θ` (eq. 4) for the structured constraint sets
//! the paper considers.
//!
//! * `None` — unconstrained least squares (Fig. 1).
//! * `HardThreshold(u)` — the `H_u` operator of Garg–Khandekar IHT used
//!   for sparse recovery (Figs. 2–3): keep the `u` largest-magnitude
//!   coordinates, zero the rest.
//! * `L2Ball(R)` — `{θ : ‖θ‖₂ ≤ R}` (Theorem 1's setting).
//! * `L1Ball(R)` — `{θ : ‖θ‖₁ ≤ R}` via the Duchi et al. (2008) simplex
//!   algorithm; the decomposable-regularizer example from Remark 1.
//!
//! Every operator is non-expansive onto its (convex) set; `HardThreshold`
//! is the one non-convex member and satisfies the weaker "best u-term
//! approximation" property instead. Property tests cover both.

/// A projection operator onto a constraint set `Θ`.
#[derive(Debug, Clone, PartialEq)]
pub enum Projection {
    /// Identity (unconstrained problem).
    None,
    /// Keep the `u` largest-magnitude coordinates (IHT's `H_u`).
    HardThreshold(usize),
    /// Euclidean ball of radius `r`.
    L2Ball(f64),
    /// ℓ1 ball of radius `r`.
    L1Ball(f64),
}

impl Projection {
    /// Apply in place.
    pub fn apply(&self, theta: &mut [f64]) {
        match *self {
            Projection::None => {}
            Projection::HardThreshold(u) => hard_threshold(theta, u),
            Projection::L2Ball(r) => project_l2_ball(theta, r),
            Projection::L1Ball(r) => project_l1_ball(theta, r),
        }
    }

    /// Does `theta` (approximately) satisfy the constraint?
    pub fn contains(&self, theta: &[f64], tol: f64) -> bool {
        match *self {
            Projection::None => true,
            Projection::HardThreshold(u) => {
                theta.iter().filter(|&&v| v != 0.0).count() <= u
            }
            Projection::L2Ball(r) => crate::linalg::norm2(theta) <= r + tol,
            Projection::L1Ball(r) => theta.iter().map(|v| v.abs()).sum::<f64>() <= r + tol,
        }
    }
}

/// `H_u`: zero all but the `u` largest-magnitude coordinates.
/// O(k) selection via quickselect on a scratch copy; ties broken toward
/// lower indices (deterministic). Magnitudes are ranked with
/// [`f64::total_cmp`], which is total over NaN — a NaN coordinate ranks
/// above every finite magnitude (and is kept) instead of panicking the
/// comparator mid-sort.
pub fn hard_threshold(theta: &mut [f64], u: usize) {
    use std::cmp::Ordering;

    let k = theta.len();
    if u >= k {
        return;
    }
    if u == 0 {
        theta.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    // Find the magnitude of the u-th largest entry.
    let mut mags: Vec<f64> = theta.iter().map(|v| v.abs()).collect();
    let thresh = {
        let idx = u - 1;
        // select_nth_unstable sorts descending around the pivot.
        let (_, t, _) = mags.select_nth_unstable_by(idx, |a, b| b.total_cmp(a));
        *t
    };
    // Keep entries strictly above the threshold, then fill remaining
    // capacity with ties (scanning left to right for determinism). The
    // same total order as the selection above, so exactly u survive even
    // when the threshold is NaN.
    let mut kept = theta
        .iter()
        .filter(|v| v.abs().total_cmp(&thresh) == Ordering::Greater)
        .count();
    for v in theta.iter_mut() {
        match v.abs().total_cmp(&thresh) {
            Ordering::Greater => {}
            Ordering::Equal if kept < u => kept += 1,
            _ => *v = 0.0,
        }
    }
}

/// Project onto `{θ : ‖θ‖₂ ≤ r}` (rescale if outside).
pub fn project_l2_ball(theta: &mut [f64], r: f64) {
    let n = crate::linalg::norm2(theta);
    if n > r {
        let s = r / n;
        for v in theta.iter_mut() {
            *v *= s;
        }
    }
}

/// Project onto `{θ : ‖θ‖₁ ≤ r}` — Duchi et al. (ICML 2008).
pub fn project_l1_ball(theta: &mut [f64], r: f64) {
    let l1: f64 = theta.iter().map(|v| v.abs()).sum();
    if l1 <= r {
        return;
    }
    // A non-finite norm (NaN/inf coordinate) has no meaningful
    // projection; leave θ unchanged rather than tripping the rho > 0
    // invariant below on vacuous comparisons.
    if !l1.is_finite() {
        return;
    }
    // Find the soft threshold tau via the sorted-magnitudes formula.
    let mut mags: Vec<f64> = theta.iter().map(|v| v.abs()).collect();
    mags.sort_unstable_by(|a, b| b.total_cmp(a));
    let mut cumsum = 0.0;
    let mut rho = 0;
    let mut tau = 0.0;
    for (j, &m) in mags.iter().enumerate() {
        cumsum += m;
        let t = (cumsum - r) / (j + 1) as f64;
        if m > t {
            rho = j + 1;
            tau = t;
        } else {
            break;
        }
    }
    debug_assert!(rho > 0);
    for v in theta.iter_mut() {
        let m = v.abs() - tau;
        *v = if m > 0.0 { v.signum() * m } else { 0.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn none_is_identity() {
        let mut v = vec![1.0, -2.0, 3.0];
        Projection::None.apply(&mut v);
        assert_eq!(v, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn hard_threshold_keeps_largest() {
        let mut v = vec![3.0, -1.0, 4.0, -1.5, 0.5];
        hard_threshold(&mut v, 2);
        assert_eq!(v, vec![3.0, 0.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn hard_threshold_u_zero_and_u_ge_k() {
        let mut v = vec![1.0, 2.0];
        hard_threshold(&mut v, 0);
        assert_eq!(v, vec![0.0, 0.0]);
        let mut w = vec![1.0, 2.0];
        hard_threshold(&mut w, 5);
        assert_eq!(w, vec![1.0, 2.0]);
    }

    #[test]
    fn hard_threshold_exact_count_with_ties() {
        let mut v = vec![1.0, -1.0, 1.0, 1.0];
        hard_threshold(&mut v, 2);
        let nnz = v.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nnz, 2);
        // Ties broken toward lower indices.
        assert_eq!(v, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn hard_threshold_is_best_u_term_approx() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let k = 2 + rng.below(20);
            let u = rng.below(k + 1);
            let orig = rng.gaussian_vec(k);
            let mut ht = orig.clone();
            hard_threshold(&mut ht, u);
            // Error of H_u equals the sum of squares of the k-u smallest
            // magnitudes — no u-sparse vector does better.
            let err: f64 = orig.iter().zip(&ht).map(|(a, b)| (a - b) * (a - b)).sum();
            let mut mags: Vec<f64> = orig.iter().map(|v| v * v).collect();
            mags.sort_by(|a, b| b.total_cmp(a));
            let best: f64 = mags.iter().skip(u).sum();
            assert!((err - best).abs() < 1e-10, "err {err} vs best {best}");
        }
    }

    #[test]
    fn l2_ball_projection() {
        let mut v = vec![3.0, 4.0];
        project_l2_ball(&mut v, 1.0);
        assert!((crate::linalg::norm2(&v) - 1.0).abs() < 1e-12);
        assert!((v[0] - 0.6).abs() < 1e-12 && (v[1] - 0.8).abs() < 1e-12);
        // Inside: untouched.
        let mut w = vec![0.1, 0.1];
        project_l2_ball(&mut w, 1.0);
        assert_eq!(w, vec![0.1, 0.1]);
    }

    #[test]
    fn l1_ball_known_case() {
        let mut v = vec![2.0, 1.0];
        project_l1_ball(&mut v, 1.0);
        // Solution: soft threshold tau = 1: (1, 0).
        assert!((v[0] - 1.0).abs() < 1e-12, "{v:?}");
        assert!(v[1].abs() < 1e-12);
    }

    #[test]
    fn l1_ball_feasible_and_optimal() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let k = 2 + rng.below(10);
            let r = 0.1 + rng.uniform() * 3.0;
            let orig = rng.gaussian_vec(k);
            let mut proj = orig.clone();
            project_l1_ball(&mut proj, r);
            let l1: f64 = proj.iter().map(|v| v.abs()).sum();
            assert!(l1 <= r + 1e-9, "l1 {l1} > r {r}");
            // Optimality spot-check: projection no farther than any of a
            // few random feasible points.
            let d_proj = crate::linalg::dist2(&orig, &proj);
            for _ in 0..10 {
                let mut cand = rng.gaussian_vec(k);
                project_l1_ball(&mut cand, r);
                let d_cand = crate::linalg::dist2(&orig, &cand);
                assert!(d_proj <= d_cand + 1e-9);
            }
        }
    }

    #[test]
    fn projections_are_non_expansive() {
        // ‖P(a) − P(b)‖ ≤ ‖a − b‖ for the convex projections (Thm 1's
        // key property).
        let mut rng = Rng::new(3);
        for proj in [Projection::L2Ball(1.3), Projection::L1Ball(2.0)] {
            for _ in 0..100 {
                let k = 2 + rng.below(8);
                let a = rng.gaussian_vec(k);
                let b = rng.gaussian_vec(k);
                let mut pa = a.clone();
                let mut pb = b.clone();
                proj.apply(&mut pa);
                proj.apply(&mut pb);
                let before = crate::linalg::dist2(&a, &b);
                let after = crate::linalg::dist2(&pa, &pb);
                assert!(after <= before + 1e-9, "{proj:?}: {after} > {before}");
            }
        }
    }

    #[test]
    fn idempotence() {
        let mut rng = Rng::new(4);
        for proj in [
            Projection::HardThreshold(3),
            Projection::L2Ball(1.0),
            Projection::L1Ball(1.5),
        ] {
            for _ in 0..50 {
                let mut v = rng.gaussian_vec(8);
                proj.apply(&mut v);
                let once = v.clone();
                proj.apply(&mut v);
                for (a, b) in v.iter().zip(&once) {
                    assert!((a - b).abs() < 1e-10, "{proj:?} not idempotent");
                }
            }
        }
    }

    #[test]
    fn nan_inputs_do_not_panic() {
        // A NaN coordinate (e.g. an upstream 0/0) must never panic a
        // projection. hard_threshold ranks NaN as the largest magnitude
        // and keeps it deterministically.
        let mut v = vec![1.0, f64::NAN, 3.0, 2.0];
        hard_threshold(&mut v, 2);
        assert!(v[1].is_nan(), "{v:?}");
        assert_eq!((v[0], v[2], v[3]), (0.0, 3.0, 0.0), "{v:?}");

        // The ball projections leave a non-finite-norm vector unchanged.
        let mut w = vec![f64::NAN, 5.0];
        project_l1_ball(&mut w, 1.0);
        assert!(w[0].is_nan() && w[1] == 5.0, "{w:?}");
        let mut z = vec![f64::NAN, 5.0];
        project_l2_ball(&mut z, 1.0);
        assert!(z[0].is_nan() && z[1] == 5.0, "{z:?}");

        // And the enum dispatch path.
        for proj in [
            Projection::HardThreshold(1),
            Projection::L1Ball(1.0),
            Projection::L2Ball(1.0),
        ] {
            let mut t = vec![f64::NAN, 1.0, -2.0];
            proj.apply(&mut t);
        }
    }

    #[test]
    fn contains_agrees_with_apply() {
        let mut rng = Rng::new(5);
        for proj in [
            Projection::HardThreshold(3),
            Projection::L2Ball(1.0),
            Projection::L1Ball(1.5),
        ] {
            for _ in 0..50 {
                let mut v = rng.gaussian_vec(8);
                proj.apply(&mut v);
                assert!(proj.contains(&v, 1e-9), "{proj:?}");
            }
        }
    }
}
