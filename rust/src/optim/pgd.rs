//! Centralised projected gradient descent (the single-node oracle).
//!
//! Implements eq. (10): `θ_t = P_Θ(θ_{t-1} − η(Mθ_{t-1} − b))`. This is
//! the exact-gradient reference every distributed scheme is measured
//! against: a scheme that decodes the gradient exactly must match this
//! trajectory step for step.

use super::convergence::{ConvergenceRule, StopReason};
use super::projections::Projection;
use crate::data::RegressionProblem;

/// Options for the PGD loop.
#[derive(Debug, Clone)]
pub struct PgdOptions {
    /// Step size `η` (`None` = spectral `1/λ_max(M)`).
    pub step_size: Option<f64>,
    /// Projection `P_Θ`.
    pub projection: Projection,
    /// Stop rule.
    pub rule: ConvergenceRule,
    /// Hard cap on steps `T`.
    pub max_steps: usize,
    /// Record the loss/error trace every `trace_every` steps (0 = never).
    pub trace_every: usize,
}

impl Default for PgdOptions {
    fn default() -> Self {
        PgdOptions {
            step_size: None,
            projection: Projection::None,
            rule: ConvergenceRule::Never,
            max_steps: 1000,
            trace_every: 0,
        }
    }
}

/// Result of an optimization run.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Final iterate.
    pub theta: Vec<f64>,
    /// Steps actually executed.
    pub steps: usize,
    /// Why the loop stopped.
    pub stop: StopReason,
    /// `(step, loss, ‖θ−θ*‖)` samples (if tracing was enabled).
    pub samples: Vec<(usize, f64, f64)>,
}

/// Run exact projected gradient descent on a regression problem.
pub fn pgd(problem: &RegressionProblem, opts: &PgdOptions) -> Trace {
    let k = problem.k();
    let eta = opts.step_size.unwrap_or_else(|| problem.spectral_step_size());
    let mut theta = vec![0.0; k];
    let mut samples = Vec::new();
    let mut grad = vec![0.0; k];

    for t in 1..=opts.max_steps {
        // grad = M θ − b
        problem.moment.matvec_into(&theta, &mut grad);
        for (g, b) in grad.iter_mut().zip(&problem.b) {
            *g -= b;
        }
        for (th, g) in theta.iter_mut().zip(&grad) {
            *th -= eta * g;
        }
        opts.projection.apply(&mut theta);

        if ConvergenceRule::is_diverged(&theta) {
            return Trace { theta, steps: t, stop: StopReason::Diverged, samples };
        }
        if opts.trace_every > 0 && t % opts.trace_every == 0 {
            samples.push((
                t,
                problem.loss(&theta),
                crate::linalg::dist2(&theta, &problem.theta_star),
            ));
        }
        if opts.rule.is_converged(&theta, Some(&grad)) {
            return Trace { theta, steps: t, stop: StopReason::Converged, samples };
        }
    }
    Trace { theta, steps: opts.max_steps, stop: StopReason::MaxSteps, samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthConfig;

    #[test]
    fn converges_on_overdetermined_ls() {
        let p = RegressionProblem::generate(&SynthConfig::dense(128, 16), 1);
        let opts = PgdOptions {
            rule: ConvergenceRule::DistanceToTruth {
                theta_star: p.theta_star.clone(),
                tol: 1e-6,
            },
            max_steps: 5000,
            ..Default::default()
        };
        let tr = pgd(&p, &opts);
        assert_eq!(tr.stop, StopReason::Converged, "steps {}", tr.steps);
        assert!(tr.steps < 5000);
        assert!(p.relative_error(&tr.theta) < 1e-6);
    }

    #[test]
    fn iht_recovers_sparse_underdetermined() {
        // k > m with u-sparse truth: IHT (PGD + H_u) recovers θ*.
        let u = 5;
        let p = RegressionProblem::generate(&SynthConfig::sparse(80, 160, u), 2);
        let opts = PgdOptions {
            projection: Projection::HardThreshold(u),
            rule: ConvergenceRule::DistanceToTruth {
                theta_star: p.theta_star.clone(),
                tol: 1e-6,
            },
            max_steps: 3000,
            ..Default::default()
        };
        let tr = pgd(&p, &opts);
        assert_eq!(tr.stop, StopReason::Converged, "steps {}", tr.steps);
    }

    #[test]
    fn plain_gd_fails_underdetermined_but_iht_succeeds() {
        // Without the sparsity projection the underdetermined problem is
        // not identifiable — PGD converges to *a* minimizer, not θ*.
        let p = RegressionProblem::generate(&SynthConfig::sparse(60, 120, 4), 3);
        let base = PgdOptions { max_steps: 2000, ..Default::default() };
        let no_proj = pgd(&p, &base);
        let with_proj = pgd(
            &p,
            &PgdOptions { projection: Projection::HardThreshold(4), ..base.clone() },
        );
        let err_no = crate::linalg::dist2(&no_proj.theta, &p.theta_star);
        let err_with = crate::linalg::dist2(&with_proj.theta, &p.theta_star);
        assert!(err_with < 1e-4, "IHT error {err_with}");
        assert!(err_no > 10.0 * err_with.max(1e-12), "GD error {err_no} should be larger");
    }

    #[test]
    fn loss_monotone_under_spectral_step() {
        let p = RegressionProblem::generate(&SynthConfig::dense(64, 8), 4);
        let opts = PgdOptions { max_steps: 50, trace_every: 1, ..Default::default() };
        let tr = pgd(&p, &opts);
        for w in tr.samples.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9, "loss increased: {} -> {}", w[0].1, w[1].1);
        }
    }

    #[test]
    fn divergence_detected_with_huge_step() {
        let p = RegressionProblem::generate(&SynthConfig::dense(64, 8), 5);
        let opts = PgdOptions {
            step_size: Some(1e6),
            max_steps: 10_000,
            ..Default::default()
        };
        let tr = pgd(&p, &opts);
        assert_eq!(tr.stop, StopReason::Diverged);
    }

    #[test]
    fn max_steps_respected() {
        let p = RegressionProblem::generate(&SynthConfig::dense(32, 4), 6);
        let opts = PgdOptions { max_steps: 3, ..Default::default() };
        let tr = pgd(&p, &opts);
        assert_eq!(tr.steps, 3);
        assert_eq!(tr.stop, StopReason::MaxSteps);
    }
}
