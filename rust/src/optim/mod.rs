//! Optimization substrate: projections, PGD, PSGD, convergence rules.

pub mod convergence;
pub mod pgd;
pub mod projections;
pub mod psgd;

pub use convergence::{ConvergenceRule, StopReason};
pub use pgd::{pgd, PgdOptions, Trace};
pub use projections::Projection;
pub use psgd::{psgd, PsgdOptions};
