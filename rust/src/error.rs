//! Crate-wide error type.

use thiserror::Error;

/// Result alias used throughout the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors surfaced by the moment-ldpc library.
#[derive(Debug, Error)]
pub enum Error {
    /// Invalid configuration or parameters (dimension mismatch, bad code
    /// parameters, ...).
    #[error("invalid configuration: {0}")]
    Config(String),

    /// A linear-algebra routine failed (singular matrix, non-convergence).
    #[error("linear algebra error: {0}")]
    Linalg(String),

    /// Code construction failed (e.g. could not build a simple regular
    /// bipartite graph, or no invertible parity submatrix was found).
    #[error("code construction error: {0}")]
    Code(String),

    /// Erasure decoding failed (too many erasures for an exact decoder).
    #[error("decode error: {0}")]
    Decode(String),

    /// The distributed runtime failed (a worker panicked or a channel was
    /// closed unexpectedly).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// A PJRT artifact was missing or failed to load/compile/execute.
    #[error("pjrt error: {0}")]
    Pjrt(String),

    /// I/O error (reading artifacts, writing reports).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Error from the underlying `xla` crate.
    #[error("xla error: {0}")]
    Xla(String),
}

impl Error {
    /// Shorthand constructor for configuration errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
