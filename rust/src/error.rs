//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: the offline crate set has no
//! `thiserror`, and the enum is small enough that the derive buys
//! nothing.

use std::fmt;

/// Result alias used throughout the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors surfaced by the moment-ldpc library.
#[derive(Debug)]
pub enum Error {
    /// Invalid configuration or parameters (dimension mismatch, bad code
    /// parameters, ...).
    Config(String),

    /// A linear-algebra routine failed (singular matrix, non-convergence,
    /// shape overflow).
    Linalg(String),

    /// Code construction failed (e.g. could not build a simple regular
    /// bipartite graph, or no invertible parity submatrix was found).
    Code(String),

    /// Erasure decoding failed (too many erasures for an exact decoder).
    Decode(String),

    /// The distributed runtime failed (a worker panicked or a channel was
    /// closed unexpectedly).
    Runtime(String),

    /// A PJRT artifact was missing or failed to load/compile/execute.
    Pjrt(String),

    /// I/O error (reading artifacts, writing reports).
    Io(std::io::Error),

    /// Error from the underlying XLA/PJRT layer.
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "invalid configuration: {m}"),
            Error::Linalg(m) => write!(f, "linear algebra error: {m}"),
            Error::Code(m) => write!(f, "code construction error: {m}"),
            Error::Decode(m) => write!(f, "decode error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Pjrt(m) => write!(f, "pjrt error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for configuration errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
}

impl From<crate::runtime::xla_stub::Error> for Error {
    fn from(e: crate::runtime::xla_stub::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(format!("{}", Error::Config("x".into())), "invalid configuration: x");
        assert_eq!(format!("{}", Error::Linalg("y".into())), "linear algebra error: y");
        assert_eq!(format!("{}", Error::Pjrt("z".into())), "pjrt error: z");
    }

    #[test]
    fn io_conversion_preserves_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
