//! Hand-rolled CLI argument parsing (no `clap` in the offline crate set).
//!
//! Grammar: `moment-ldpc <subcommand> [--flag value]... [--switch]...`.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed arguments: a subcommand, `--key value` flags, and bare
/// `--switch` toggles.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first positional).
    pub command: String,
    /// Remaining positionals.
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

/// Flags that take no value. (`--trace` is NOT here: it takes the
/// output path.)
const SWITCHES: &[&str] = &["quick", "json", "help", "async"];

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if SWITCHES.contains(&name) {
                    args.switches.push(name.to_string());
                } else {
                    let val = it.next().ok_or_else(|| {
                        Error::Config(format!("flag --{name} expects a value"))
                    })?;
                    args.flags.insert(name.to_string(), val);
                }
            } else if args.command.is_empty() {
                args.command = a;
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Get a typed flag with a default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| {
                Error::Config(format!("flag --{name}: cannot parse '{v}'"))
            }),
        }
    }

    /// Get an optional flag.
    pub fn get_opt<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| Error::Config(format!("flag --{name}: cannot parse '{v}'"))),
        }
    }

    /// Raw string flag.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Is a switch present?
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// The CLI usage text.
pub const USAGE: &str = "\
moment-ldpc — robust distributed gradient descent via LDPC moment encoding

USAGE: moment-ldpc <command> [flags]

COMMANDS:
  run        Run one distributed optimization
             --scheme ldpc|mds|uncoded|replication|ksdy-hadamard|ksdy-gaussian|gradcoding
             --m N --k N [--sparsity U] --workers W --stragglers S
             --decode-iters D --rel-tol T --max-steps N --trials N
             [--decoder peel|ladder] LDPC erasure decoder (default
               ladder: escalates peeling stalls through a BP pass and
               an exact inactivation solve; peel = the paper's greedy
               D-iteration decoder, which zeroes whatever stalls)
             --backend native|pjrt [--json]
             [--trace PATH] write a timeline of trial 0 (per-worker
               lanes; wall-clock ns) [--trace-format chrome|jsonl]
               (chrome = Perfetto-loadable trace_event JSON, jsonl =
               one step record per line) [--trace-ring N] per-lane
               span-ring capacity (default 4096; overflow keeps the
               newest spans and counts the dropped)
             [--faults SPEC] [--retries N ...] fault injection and
               re-dispatch, as in `simulate` (crash-restart degrades to
               crash-stop here: an OS thread cannot rejoin)
             [--cluster threads|tcp] execution backend (default
               threads: one OS thread per worker in-process)
               [--addrs HOST:PORT,...] tcp daemon addresses; logical
                 workers map onto them round-robin, so W workers can
                 share fewer daemon processes
               [--connect-timeout-ms F] [--redial-timeout-ms F]
               [--heartbeat-ms F] [--heartbeat-misses N] failure
                 detection: a connection silent for F*N ms is declared
                 down; its shards re-dispatch to survivors (--retries)
               [--capture-trace PATH] record trial 0's per-worker
                 per-step collect latencies (ms) as a table replayable
                 with `simulate --latency trace --trace-table PATH`
               (injected --faults are thread/sim-only; over tcp, kill a
                worker process instead — detection is socket-level)
  worker     Serve coded-gradient steps over TCP until shut down
             --listen HOST:PORT (port 0 picks an ephemeral port; the
               daemon prints `listening HOST:PORT` on stdout)
             [--backend native|pjrt] [--exit-after N] exit(86) before
               the (N+1)-th served step — deterministic crash injection
               for tests and demos
  simulate   Virtual-time run: deadline-driven collection over simulated
             workers (scales past host cores; default 512 workers)
             --workers N --m N --k N --scheme <as run> --trials N
             [--decoder peel|ladder] as in `run`
             --latency shifted-exp|pareto|markov|hetero|trace
               [--shift-ms F --rate F] [--scale-ms F --shape F]
               [--slowdown F --p-slow F --p-fast F] [--spread F]
               [--trace-table PATH] (trace) replay a latency table
                 captured from a real cluster by `run --cluster tcp
                 --capture-trace PATH`; steps wrap past the end
             --policy all|wait-k|wait-fresh|deadline|quantile|mirror
               [--wait-k N] [--deadline-ms F]
               [--quantile F --slack F --window N] [--mirror-stragglers S]
             [--async] asynchronous pipelined master (laggards keep
               computing; stale responses applied within the bound)
               [--staleness S] max applied staleness (default 1; S=0
                 replays the synchronous simulator bit for bit)
               [--flops-per-ms F] flop-aware compute times (latency
                 draws become per-worker slowdown multipliers)
               [--nic-gbps F --nic-overhead-ms F] master-NIC contention
                 (broadcasts and responses serialize on one link)
               [--racks N --rack-gbps F --rack-overhead-ms F]
                 hierarchical topology: N racks with their own NICs
                 uplinking into the master link (θ fans out per rack,
                 responses queue twice; racks=1 = flat; rack NIC
                 defaults to the master link's parameters)
             [--collective star|ring|tree|gossip] aggregation schedule
               (default star = master fan-out/fan-in). Ring pipelines
               2(W-1) segment hops peer to peer, tree reduces in
               ceil(log2 W) hop levels, gossip pushes epidemically on a
               seeded stream; non-star hops are priced by the NIC
               topology (add --nic-gbps), so the master link stops
               serializing the collection
             [--faults SPEC] deterministic fault injection, composable
               with every latency model; SPEC = comma-separated
               crash:P | crash-restart:P:MS | corrupt:P | omit:P
               (per-worker per-step probabilities; corrupted arrivals
               are checksum-detected and erased, never decoded)
             [--retries N] master-side re-dispatch of lost blocks to
               survivors, with capped exponential backoff
               [--backoff-ms F --backoff-cap-ms F --timeout-ms F]
             --max-steps N --rel-tol T [--json]
             [--trace PATH] timeline of trial 0 in virtual ms
               [--trace-format chrome|jsonl] [--trace-ring N]
               (same semantics as `run`)
  fig1       Reproduce Figure 1 (least squares)        [--trials N] [--quick]
  fig2       Reproduce Figure 2 (sparse, m > k)        [--trials N] [--quick]
  fig3       Reproduce Figure 3 (sparse, k > m)        [--trials N] [--quick]
  density    Density-evolution table (Prop. 2)         [--l N --r N]
  artifacts  List discovered AOT artifacts             [--dir PATH]
  help       Show this message
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse("run --m 2048 --k 400 --quick");
        assert_eq!(a.command, "run");
        assert_eq!(a.get::<usize>("m", 0).unwrap(), 2048);
        assert_eq!(a.get::<usize>("k", 0).unwrap(), 400);
        assert!(a.has("quick"));
        assert!(!a.has("json"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get::<usize>("m", 7).unwrap(), 7);
        assert_eq!(a.get_str("scheme", "ldpc"), "ldpc");
        assert_eq!(a.get_opt::<f64>("step").unwrap(), None);
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("run --m abc");
        assert!(a.get::<usize>("m", 0).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(["run".to_string(), "--m".to_string()]).is_err());
    }

    #[test]
    fn trace_takes_a_path_value() {
        let a = parse("simulate --trace out/t.json --trace-format jsonl --json");
        assert_eq!(a.get_str("trace", ""), "out/t.json");
        assert_eq!(a.get_str("trace-format", "chrome"), "jsonl");
        assert!(a.has("json"));
        assert!(!a.has("trace"));
    }

    #[test]
    fn positionals_collected() {
        let a = parse("run extra1 extra2");
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }
}
