//! Structured tracing & per-worker timelines across all three backends.
//!
//! A [`Tracer`] holds one bounded span ring per *lane* (lane 0 is the
//! master, lane `j + 1` is worker `j`) plus a JSONL step-record
//! stream. Spans carry a [`SpanKind`], begin/end timestamps, and the
//! step/task ids they belong to. Timestamps live in the tracer's
//! [`TimeDomain`]: wall-clock nanoseconds since the tracer's origin
//! for the OS-thread cluster, virtual milliseconds for the
//! synchronous and asynchronous simulators.
//!
//! **Hard invariant** (pinned by `tests/integration_obs.rs`): tracing
//! draws from no RNG stream and touches no scheduling decision. Every
//! emission site only *reads* values the backend already computed, so
//! traced and untraced runs are bit-identical in θ and fault
//! counters. A disarmed tracer is an `Option::None` field in each
//! executor — the no-op path is a single branch.
//!
//! Exporters: [`Tracer::to_chrome_json`] renders Chrome
//! `trace_event` JSON loadable in Perfetto / `chrome://tracing`
//! (per-worker lanes + a master lane); [`Tracer::to_jsonl`] streams
//! one JSON step record per line. Both are armed from the CLI via
//! `--trace PATH [--trace-format chrome|jsonl]` on `run` and
//! `simulate`, and from the harness via [`TraceSpec`].

pub mod export;
pub mod hist;

pub use hist::LogHistogram;

use std::cell::RefCell;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use crate::error::Result;

/// Default per-lane span-ring capacity.
pub const DEFAULT_RING_CAP: usize = 4096;

/// What a span measures. The taxonomy is the union of the interesting
/// boundaries across the three backends; any single run only emits
/// the subset its backend has.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Master lane: one whole optimization step.
    Step,
    /// Master lane: one-time scheme construction / moment encoding.
    Encode,
    /// Master lane: θ broadcast / fan-out window.
    Broadcast,
    /// Master lane: collection window (first dispatch → cutoff).
    Collect,
    /// Master lane: modeled communication cost (`CommModel`).
    Comm,
    /// Master lane: `decode_into` (erasure decode of the step).
    Decode,
    /// Master lane: one peeling round inside the decode; `task` holds
    /// the number of peel operations in the round. Placement inside
    /// the decode span is schematic (rounds are not timed
    /// individually).
    PeelRound,
    /// Master lane: one BP escalation round of the decode ladder;
    /// `task` holds the ops the round resolved (component resolution
    /// plus the re-peeling it unlocked). Placement is schematic, like
    /// `PeelRound`.
    BpRound,
    /// Master lane instant: the decode ladder's inactivation
    /// (Gauss–Jordan) rung fired; `task` holds the coordinates it
    /// solved.
    Inactivation,
    /// Master lane: θ update + projection.
    Update,
    /// Worker lane: task compute (dispatch/θ-receipt → completion).
    Compute,
    /// Worker lane: waiting at the rack for the θ relay
    /// (hierarchical topologies).
    ThetaWait,
    /// Worker lane: rack-uplink FIFO wait + transfer.
    NicRack,
    /// Worker lane: master-link FIFO wait + transfer.
    NicMaster,
    /// Worker lane instant: result accepted by the master.
    Arrival,
    /// Worker lane instant: arrival erased by the checksum
    /// (corruption).
    CorruptErase,
    /// Worker lane instant: task omitted (never delivered).
    Omitted,
    /// Worker lane: a re-dispatched task's flight (launch → arrival).
    Retry,
    /// Worker lane: an in-flight task cancelled by staleness doom.
    Cancelled,
    /// Worker lane: crash → restart window.
    Down,
    /// Worker lane instant: straggler cut off at the deadline.
    Dropped,
    /// Master lane: TCP dial + hello handshake (networked cluster);
    /// `task` holds the address index.
    Connect,
    /// Master lane instant: the heartbeat monitor declared a
    /// connection dead; `task` holds the address index.
    Heartbeat,
    /// Master lane instant: a previously-down worker address was
    /// re-dialed and rejoined the dispatch set (elastic membership);
    /// `task` holds the address index.
    Reconnect,
    /// Worker lane: θ (or a segment of it) in flight over a
    /// worker↔worker peer edge — a non-star collective's fan-out hop.
    NicPeer,
    /// Master lane: a non-star collective's post-cut reduce phase
    /// (ring/tree/gossip critical path down to the master); `task`
    /// holds the participating-member count.
    ReduceHop,
}

impl SpanKind {
    /// Stable lowercase name (used as the Chrome event name).
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Step => "step",
            SpanKind::Encode => "encode",
            SpanKind::Broadcast => "broadcast",
            SpanKind::Collect => "collect",
            SpanKind::Comm => "comm",
            SpanKind::Decode => "decode",
            SpanKind::PeelRound => "peel_round",
            SpanKind::BpRound => "bp_round",
            SpanKind::Inactivation => "inactivation",
            SpanKind::Update => "update",
            SpanKind::Compute => "compute",
            SpanKind::ThetaWait => "theta_wait",
            SpanKind::NicRack => "nic_rack",
            SpanKind::NicMaster => "nic_master",
            SpanKind::Arrival => "arrival",
            SpanKind::CorruptErase => "corrupt_erase",
            SpanKind::Omitted => "omitted",
            SpanKind::Retry => "retry",
            SpanKind::Cancelled => "cancelled",
            SpanKind::Down => "down",
            SpanKind::Dropped => "dropped",
            SpanKind::Connect => "connect",
            SpanKind::Heartbeat => "heartbeat",
            SpanKind::Reconnect => "reconnect",
            SpanKind::NicPeer => "nic_peer",
            SpanKind::ReduceHop => "reduce_hop",
        }
    }
}

/// One traced interval (or instant, when `begin == end`). Times are in
/// the owning tracer's [`TimeDomain`] units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    /// What the interval measures.
    pub kind: SpanKind,
    /// Lane: 0 = master, `j + 1` = worker `j`.
    pub lane: u32,
    /// Optimization step the span belongs to.
    pub step: u32,
    /// Task id (or a kind-specific payload, e.g. peel ops per round).
    pub task: u64,
    /// Begin timestamp (wall ns or virtual ms).
    pub begin: f64,
    /// End timestamp; `== begin` for instants.
    pub end: f64,
}

/// Bounded per-lane ring: overwrites the oldest span when full, so the
/// newest spans always survive, and counts what it dropped.
#[derive(Debug, Clone, Default)]
struct SpanRing {
    spans: Vec<Span>,
    head: usize,
    dropped: u64,
}

impl SpanRing {
    fn push(&mut self, cap: usize, s: Span) {
        if self.spans.len() < cap {
            self.spans.push(s);
        } else {
            self.spans[self.head] = s;
            self.head = (self.head + 1) % cap;
            self.dropped += 1;
        }
    }

    /// Oldest-first iteration.
    fn iter(&self) -> impl Iterator<Item = &Span> {
        self.spans[self.head..].iter().chain(self.spans[..self.head].iter())
    }
}

/// Which clock a tracer's timestamps live on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeDomain {
    /// Wall-clock nanoseconds since the tracer's creation (the
    /// OS-thread cluster).
    WallNs,
    /// Virtual milliseconds (the synchronous and asynchronous
    /// simulators); advanced by the executors via
    /// [`Tracer::set_cursor`].
    VirtualMs,
}

/// Output format for a trace file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// Chrome `trace_event` JSON (Perfetto / `chrome://tracing`).
    Chrome,
    /// One JSON step record per line.
    Jsonl,
}

impl TraceFormat {
    /// Parse a CLI flag value.
    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s {
            "chrome" => Some(TraceFormat::Chrome),
            "jsonl" => Some(TraceFormat::Jsonl),
            _ => None,
        }
    }

    /// The CLI spelling.
    pub fn label(self) -> &'static str {
        match self {
            TraceFormat::Chrome => "chrome",
            TraceFormat::Jsonl => "jsonl",
        }
    }
}

/// Where and how to write a trace — the harness-level arming knob.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Output file path (parent directories are created).
    pub path: PathBuf,
    /// Output format.
    pub format: TraceFormat,
    /// Per-lane span-ring capacity ([`DEFAULT_RING_CAP`] if built via
    /// the constructors).
    pub ring_capacity: usize,
}

impl TraceSpec {
    /// Chrome-format spec with the default ring capacity.
    pub fn chrome(path: impl Into<PathBuf>) -> Self {
        TraceSpec { path: path.into(), format: TraceFormat::Chrome, ring_capacity: DEFAULT_RING_CAP }
    }

    /// JSONL-format spec with the default ring capacity.
    pub fn jsonl(path: impl Into<PathBuf>) -> Self {
        TraceSpec { path: path.into(), format: TraceFormat::Jsonl, ring_capacity: DEFAULT_RING_CAP }
    }
}

/// The tracer: per-lane bounded span rings + a step-record stream.
#[derive(Debug)]
pub struct Tracer {
    domain: TimeDomain,
    origin: Instant,
    cap: usize,
    lanes: Vec<SpanRing>,
    cursor: f64,
    step_lines: Vec<String>,
}

/// Shared handle: the master loop and its executor both emit into one
/// tracer. Single-threaded by construction (all emission happens on
/// the coordinating thread — worker timings are read off response
/// structs), so `Rc<RefCell<…>>` suffices.
pub type SharedTracer = Rc<RefCell<Tracer>>;

/// Wrap a tracer for sharing between the master loop and an executor.
pub fn shared(tracer: Tracer) -> SharedTracer {
    Rc::new(RefCell::new(tracer))
}

impl Tracer {
    /// Tracer with the default per-lane ring capacity.
    pub fn new(domain: TimeDomain) -> Self {
        Self::with_capacity(domain, DEFAULT_RING_CAP)
    }

    /// Tracer with an explicit per-lane ring capacity (min 1).
    pub fn with_capacity(domain: TimeDomain, cap: usize) -> Self {
        Tracer {
            domain,
            origin: Instant::now(),
            cap: cap.max(1),
            lanes: Vec::new(),
            cursor: 0.0,
            step_lines: Vec::new(),
        }
    }

    /// The tracer's clock domain.
    pub fn domain(&self) -> TimeDomain {
        self.domain
    }

    /// Current time in domain units: elapsed wall ns, or the virtual
    /// cursor last set by the executor.
    pub fn now(&self) -> f64 {
        match self.domain {
            TimeDomain::WallNs => self.origin.elapsed().as_nanos() as f64,
            TimeDomain::VirtualMs => self.cursor,
        }
    }

    /// Advance the virtual clock (no-op in the wall domain). Executors
    /// call this so master-lane spans emitted by the generic step loop
    /// line up with the simulator's clock.
    pub fn set_cursor(&mut self, t_ms: f64) {
        if self.domain == TimeDomain::VirtualMs {
            self.cursor = t_ms;
        }
    }

    /// Record a span on `lane` (0 = master, `j + 1` = worker `j`).
    pub fn span(&mut self, kind: SpanKind, lane: usize, step: usize, task: u64, begin: f64, end: f64) {
        while self.lanes.len() <= lane {
            self.lanes.push(SpanRing::default());
        }
        let s = Span { kind, lane: lane as u32, step: step as u32, task, begin, end };
        self.lanes[lane].push(self.cap, s);
    }

    /// Record an instant (zero-duration span).
    pub fn instant(&mut self, kind: SpanKind, lane: usize, step: usize, task: u64, at: f64) {
        self.span(kind, lane, step, task, at, at);
    }

    /// Record a host-measured duration (`host_ns`) as a span ending at
    /// the current time. In the wall domain the span is back-dated
    /// from now; in the virtual domain it starts at the cursor and
    /// advances it (host compute folded into virtual time, exactly as
    /// `sim_time_ms` does for the totals). Returns `(begin, end)`.
    pub fn span_host(
        &mut self,
        kind: SpanKind,
        lane: usize,
        step: usize,
        task: u64,
        host_ns: u64,
    ) -> (f64, f64) {
        match self.domain {
            TimeDomain::WallNs => {
                let end = self.now();
                let begin = (end - host_ns as f64).max(0.0);
                self.span(kind, lane, step, task, begin, end);
                (begin, end)
            }
            TimeDomain::VirtualMs => {
                let begin = self.cursor;
                let end = begin + host_ns as f64 / 1e6;
                self.cursor = end;
                self.span(kind, lane, step, task, begin, end);
                (begin, end)
            }
        }
    }

    /// Append one pre-rendered JSON object line to the step-record
    /// stream (the JSONL export).
    pub fn push_step_line(&mut self, line: String) {
        self.step_lines.push(line);
    }

    /// Number of lanes that have recorded at least one span slot.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Spans of one lane, oldest first (empty for unknown lanes).
    pub fn lane_spans(&self, lane: usize) -> Vec<Span> {
        self.lanes.get(lane).map(|r| r.iter().copied().collect()).unwrap_or_default()
    }

    /// Spans dropped from one lane's ring (overwritten by newer ones).
    pub fn dropped(&self, lane: usize) -> u64 {
        self.lanes.get(lane).map(|r| r.dropped).unwrap_or(0)
    }

    /// Total dropped spans across lanes.
    pub fn dropped_total(&self) -> u64 {
        self.lanes.iter().map(|r| r.dropped).sum()
    }

    /// Total retained spans across lanes.
    pub fn span_count(&self) -> usize {
        self.lanes.iter().map(|r| r.spans.len()).sum()
    }

    /// Render the Chrome `trace_event` JSON document.
    pub fn to_chrome_json(&self) -> String {
        export::chrome_json(self)
    }

    /// Render the JSONL step-record stream.
    pub fn to_jsonl(&self) -> String {
        export::jsonl(self)
    }

    /// Write the trace to `spec.path` in `spec.format`, creating
    /// parent directories.
    pub fn write(&self, spec: &TraceSpec) -> Result<()> {
        if let Some(parent) = spec.path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let body = match spec.format {
            TraceFormat::Chrome => self.to_chrome_json(),
            TraceFormat::Jsonl => self.to_jsonl(),
        };
        std::fs::write(&spec.path, body)?;
        Ok(())
    }

    pub(crate) fn lanes(&self) -> impl Iterator<Item = (usize, impl Iterator<Item = &Span>)> {
        self.lanes.iter().enumerate().map(|(i, r)| (i, r.iter()))
    }

    pub(crate) fn step_lines(&self) -> &[String] {
        &self.step_lines
    }
}

/// JSON number in shortest `Display` form; non-finite → `null` (JSON
/// has no NaN/Inf literals).
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

/// NaN/Inf-guard for a pre-rendered number: the rendering if `v` is
/// finite, `null` otherwise. Lets callers keep their `{:.6e}`-style
/// formatting without risking invalid JSON.
pub fn json_safe(v: f64, rendered: String) -> String {
    if v.is_finite() {
        rendered
    } else {
        "null".into()
    }
}

/// JSON string literal with `\`, `"`, and control characters escaped.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overflow_keeps_newest_and_counts_dropped() {
        let mut t = Tracer::with_capacity(TimeDomain::VirtualMs, 4);
        for i in 0..10 {
            t.span(SpanKind::Compute, 1, 0, i, i as f64, i as f64 + 0.5);
        }
        let spans = t.lane_spans(1);
        assert_eq!(spans.len(), 4);
        let tasks: Vec<u64> = spans.iter().map(|s| s.task).collect();
        assert_eq!(tasks, vec![6, 7, 8, 9], "newest four survive, oldest first");
        assert_eq!(t.dropped(1), 6);
        assert_eq!(t.dropped_total(), 6);
        assert_eq!(t.span_count(), 4);
    }

    #[test]
    fn virtual_cursor_and_span_host() {
        let mut t = Tracer::new(TimeDomain::VirtualMs);
        t.set_cursor(10.0);
        assert_eq!(t.now(), 10.0);
        let (b, e) = t.span_host(SpanKind::Decode, 0, 3, 2, 2_000_000); // 2 ms
        assert_eq!((b, e), (10.0, 12.0));
        assert_eq!(t.now(), 12.0, "cursor advanced by the host duration");
        let s = t.lane_spans(0)[0];
        assert_eq!(s.kind, SpanKind::Decode);
        assert_eq!((s.step, s.task), (3, 2));
    }

    #[test]
    fn wall_domain_backdates_host_spans() {
        let mut t = Tracer::new(TimeDomain::WallNs);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let (b, e) = t.span_host(SpanKind::Update, 0, 0, 0, 1_000_000);
        assert!(e > b && (e - b - 1e6).abs() < 1.0, "{b} {e}");
        // set_cursor is a no-op on the wall clock.
        t.set_cursor(0.0);
        assert!(t.now() > 0.0);
    }

    #[test]
    fn json_num_and_safe_guard_nonfinite() {
        assert_eq!(json_num(1.5), "1.5");
        assert_eq!(json_num(-0.25), "-0.25");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert_eq!(json_num(f64::NEG_INFINITY), "null");
        assert_eq!(json_safe(2.0, "2.000e0".into()), "2.000e0");
        assert_eq!(json_safe(f64::NAN, "NaN".into()), "null");
    }

    #[test]
    fn json_str_escapes() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
    }

    #[test]
    fn write_both_formats() {
        let dir = crate::testing::TempDir::new("obs").unwrap();
        let mut t = Tracer::new(TimeDomain::VirtualMs);
        t.span(SpanKind::Compute, 1, 0, 7, 1.0, 2.0);
        t.push_step_line("{\"t\":0}".into());
        let cp = dir.path().join("sub/trace.json");
        t.write(&TraceSpec::chrome(&cp)).unwrap();
        let body = std::fs::read_to_string(&cp).unwrap();
        assert!(body.starts_with("{\"traceEvents\":["));
        let jp = dir.path().join("trace.jsonl");
        t.write(&TraceSpec::jsonl(&jp)).unwrap();
        assert_eq!(std::fs::read_to_string(&jp).unwrap(), "{\"t\":0}\n");
    }
}
