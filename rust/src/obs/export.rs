//! Trace exporters: Chrome `trace_event` JSON and JSONL step records.
//!
//! The Chrome document is the `{"traceEvents": [...]}` object form
//! with `ph: "X"` complete events — the dialect both Perfetto and
//! `chrome://tracing` load directly. Lanes map to threads of one
//! process: `tid` 0 is the master, `tid` `j + 1` is worker `j`, named
//! via `thread_name` metadata events. Timestamps (`ts`) and durations
//! (`dur`) are microseconds: wall-nanosecond tracers divide by 1e3,
//! virtual-millisecond tracers multiply by 1e3.

use super::{json_num, json_str, TimeDomain, Tracer};

/// µs per domain unit.
fn scale(domain: TimeDomain) -> f64 {
    match domain {
        TimeDomain::WallNs => 1e-3,
        TimeDomain::VirtualMs => 1e3,
    }
}

/// Render the Chrome `trace_event` JSON document.
pub(super) fn chrome_json(t: &Tracer) -> String {
    let k = scale(t.domain());
    let mut out = String::from("{\"traceEvents\":[\n");
    out.push_str(
        "{\"ph\":\"M\",\"pid\":0,\"tid\":0,\"name\":\"process_name\",\
         \"args\":{\"name\":\"moment_ldpc\"}}",
    );
    for (lane, _) in t.lanes() {
        let name = lane_name(lane);
        out.push_str(&format!(
            ",\n{{\"ph\":\"M\",\"pid\":0,\"tid\":{lane},\"name\":\"thread_name\",\
             \"args\":{{\"name\":{}}}}}",
            json_str(&name)
        ));
    }
    for (lane, spans) in t.lanes() {
        for s in spans {
            let ts = json_num(s.begin * k);
            let dur = json_num((s.end - s.begin).max(0.0) * k);
            out.push_str(&format!(
                ",\n{{\"ph\":\"X\",\"pid\":0,\"tid\":{lane},\"name\":\"{}\",\
                 \"cat\":\"{}\",\"ts\":{ts},\"dur\":{dur},\
                 \"args\":{{\"step\":{},\"task\":{}}}}}",
                s.kind.as_str(),
                s.kind.as_str(),
                s.step,
                s.task
            ));
        }
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// Render the JSONL step-record stream (one object per line).
pub(super) fn jsonl(t: &Tracer) -> String {
    let mut out = String::new();
    for line in t.step_lines() {
        out.push_str(line);
        out.push('\n');
    }
    out
}

fn lane_name(lane: usize) -> String {
    if lane == 0 {
        "master".into()
    } else {
        format!("worker {}", lane - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{SpanKind, TraceSpec, Tracer};
    use super::*;

    /// Minimal well-formedness check: balanced braces/brackets outside
    /// string literals (the full gate in ci.sh is `python3 -m
    /// json.tool`).
    fn balanced(s: &str) -> bool {
        let (mut brace, mut bracket) = (0i64, 0i64);
        let mut in_str = false;
        let mut esc = false;
        for c in s.chars() {
            if in_str {
                if esc {
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else if c == '"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                '"' => in_str = true,
                '{' => brace += 1,
                '}' => brace -= 1,
                '[' => bracket += 1,
                ']' => bracket -= 1,
                _ => {}
            }
            if brace < 0 || bracket < 0 {
                return false;
            }
        }
        brace == 0 && bracket == 0 && !in_str
    }

    #[test]
    fn chrome_lanes_scaling_and_shape() {
        let mut t = Tracer::new(TimeDomain::VirtualMs);
        t.span(SpanKind::Compute, 2, 1, 42, 1.5, 4.0); // worker 1
        t.instant(SpanKind::Arrival, 2, 1, 42, 4.0);
        t.span(SpanKind::Collect, 0, 1, 0, 0.0, 4.0);
        let body = t.to_chrome_json();
        assert!(balanced(&body), "{body}");
        assert!(body.contains("\"name\":\"process_name\""));
        assert!(body.contains("\"name\":\"master\""));
        assert!(body.contains("\"name\":\"worker 1\""));
        // 1.5 ms → 1500 µs, 2.5 ms → 2500 µs.
        assert!(body.contains("\"ts\":1500,\"dur\":2500"), "{body}");
        assert!(body.contains("\"name\":\"compute\""));
        assert!(body.contains("\"args\":{\"step\":1,\"task\":42}"));
        // Instants render with dur 0, still valid complete events.
        assert!(body.contains("\"name\":\"arrival\",\"cat\":\"arrival\",\"ts\":4000,\"dur\":0"));
    }

    #[test]
    fn chrome_wall_ns_scales_down() {
        let mut t = Tracer::new(TimeDomain::WallNs);
        t.span(SpanKind::Decode, 0, 0, 0, 2_000.0, 5_000.0); // ns
        let body = t.to_chrome_json();
        assert!(body.contains("\"ts\":2,\"dur\":3"), "{body}");
        assert!(balanced(&body));
    }

    #[test]
    fn negative_duration_clamped() {
        let mut t = Tracer::new(TimeDomain::VirtualMs);
        t.span(SpanKind::Compute, 1, 0, 0, 5.0, 4.0);
        assert!(t.to_chrome_json().contains("\"dur\":0"));
    }

    #[test]
    fn jsonl_streams_lines() {
        let mut t = Tracer::new(TimeDomain::VirtualMs);
        t.push_step_line("{\"t\":0,\"error\":1.0}".into());
        t.push_step_line("{\"t\":1,\"error\":null}".into());
        let s = jsonl(&t);
        assert_eq!(s.lines().count(), 2);
        for line in s.lines() {
            assert!(balanced(line), "{line}");
        }
    }

    #[test]
    fn empty_tracer_exports_valid_documents() {
        let t = Tracer::new(TimeDomain::WallNs);
        assert!(balanced(&t.to_chrome_json()));
        assert_eq!(t.to_jsonl(), "");
        let _ = TraceSpec::chrome("x.json"); // constructor smoke
    }
}
