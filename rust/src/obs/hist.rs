//! Log-bucketed histograms for latency-style quantities.
//!
//! 64 power-of-two buckets centred on 1.0: bucket `i` covers
//! `[2^(i-32), 2^(i-31))`, so the range spans ~2.3e-10 .. ~4.3e9 —
//! wide enough for nanosecond counters and millisecond virtual times
//! alike without any configuration. Percentiles are nearest-rank over
//! the bucket counts, reported at the geometric midpoint of the
//! selected bucket and clamped to the observed `[min, max]`, so small
//! samples never report values outside the data. Adding a sample is a
//! branch, a `log2`, and three adds — cheap enough to stay always-on
//! in [`MetricTotals`](crate::coordinator::metrics::MetricTotals).

const BUCKETS: usize = 64;
const BIAS: i32 = 32;

/// Fixed-footprint log₂-bucketed histogram (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    buckets: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    // `[u64; 64]` has no derived `Default` (std stops at 32), so spell
    // the empty histogram out.
    fn default() -> Self {
        LogHistogram {
            buckets: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(v: f64) -> usize {
        if v <= 0.0 {
            // Zero and negative samples land in the lowest bucket; the
            // exact min/max still track the true values.
            return 0;
        }
        (v.log2().floor() as i32 + BIAS).clamp(0, BUCKETS as i32 - 1) as usize
    }

    /// Record one sample. NaN samples are ignored (they carry no
    /// ordering information); ±∞ is clamped into the edge buckets.
    pub fn add(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += *o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Has anything been recorded?
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of the exact samples (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest recorded sample (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Largest recorded sample (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Nearest-rank percentile `p` in `[0, 100]`, reported at the
    /// geometric midpoint of the selected bucket clamped to
    /// `[min, max]`. NaN when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let lo = 2f64.powi(i as i32 - BIAS);
                let mid = lo * std::f64::consts::SQRT_2;
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 95th percentile.
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    /// 99th percentile.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_nan() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert!(h.mean().is_nan());
        assert!(h.p50().is_nan());
        assert!(h.min().is_nan() && h.max().is_nan());
    }

    #[test]
    fn percentiles_ordered_and_bounded() {
        let mut h = LogHistogram::new();
        for i in 1..=1000 {
            h.add(i as f64);
        }
        assert_eq!(h.count(), 1000);
        let (p50, p95, p99) = (h.p50(), h.p95(), h.p99());
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        for p in [p50, p95, p99] {
            assert!((1.0..=1000.0).contains(&p), "{p}");
        }
        // The median of 1..=1000 sits in the 512..1024 bucket; the
        // coarse estimate must land within a factor of √2·2 of 500.
        assert!((250.0..=1000.0).contains(&p50), "{p50}");
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn single_sample_is_exact() {
        let mut h = LogHistogram::new();
        h.add(7.25);
        // Bucket midpoints are coarse, but clamping to [min, max]
        // collapses a single sample to itself.
        assert_eq!(h.p50(), 7.25);
        assert_eq!(h.p99(), 7.25);
        assert_eq!(h.min(), 7.25);
        assert_eq!(h.max(), 7.25);
    }

    #[test]
    fn nan_ignored_zero_and_negative_clamped() {
        let mut h = LogHistogram::new();
        h.add(f64::NAN);
        assert!(h.is_empty());
        h.add(0.0);
        h.add(-3.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), -3.0);
        assert_eq!(h.max(), 0.0);
        let p = h.p50();
        assert!((-3.0..=0.0).contains(&p), "{p}");
    }

    #[test]
    fn merge_matches_sequential() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut all = LogHistogram::new();
        for i in 0..100 {
            let v = (i as f64) * 0.37 + 0.1;
            if i % 2 == 0 {
                a.add(v);
            } else {
                b.add(v);
            }
            all.add(v);
        }
        a.merge(&b);
        assert_eq!(a, all);
    }
}
