"""L2 — the JAX compute graphs the Rust runtime executes.

Two worker-side graphs (both built on the L1 Pallas kernel, so the
kernel lowers into the same HLO module):

* :func:`shard_matvec` — ``rows @ theta``: the entire per-step task of a
  moment-encoded worker (Scheme 1/2: one inner product per assigned
  row).
* :func:`local_grad` — ``Xᵀ(Xθ − y)``: the per-step task of a
  data-parallel worker (KSDY17 / uncoded / replication). The transpose
  mat-vec reuses the same kernel on ``Xᵀ`` (a lay-out change XLA fuses
  into the surrounding module).

And the master-side step updates (:func:`pgd_step`, :func:`iht_step`)
for completeness / ablation; the Rust master normally applies these
natively since they are O(k).

`python/compile/aot.py` lowers each graph once per artifact shape to HLO
text; Python never runs at request time.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.coded_matvec import coded_matvec


def shard_matvec(rows, theta):
    """Worker task (moment schemes): one mat-vec over the encoded shard."""
    return (coded_matvec(rows, theta),)


def local_grad(x, y, theta):
    """Worker task (data-parallel schemes): ``Xᵀ(Xθ − y)``."""
    r = coded_matvec(x, theta) - y
    g = coded_matvec(x.T, r)
    return (g,)


def pgd_step(theta, grad, eta):
    """Master update, least squares: ``θ − η·g``."""
    return (theta - eta * grad,)


def iht_step(theta, grad, eta, u: int):
    """Master update, sparse recovery: gradient step + ``H_u``."""
    t = theta - eta * grad
    k = t.shape[0]
    if u == 0:
        return (jnp.zeros_like(t),)
    if u >= k:
        return (t,)
    mags = jnp.abs(t)
    thresh = jnp.sort(mags)[k - u]
    return (jnp.where(mags >= thresh, t, 0.0),)
