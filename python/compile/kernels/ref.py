"""Pure-jnp oracles for the L1 kernels (the correctness reference).

Everything the Pallas kernel and the L2 model compute must match these
to float tolerance; pytest enforces it (``python/tests/test_kernel.py``).
"""

from __future__ import annotations

import jax.numpy as jnp


def matvec(rows, theta):
    """``rows @ theta`` — the Scheme 1/2 worker task."""
    return jnp.dot(rows, theta)


def local_grad(x, y, theta):
    """``Xᵀ(Xθ − y)`` — the KSDY17 / uncoded / replication worker task."""
    r = jnp.dot(x, theta) - y
    return jnp.dot(x.T, r)


def pgd_step(theta, grad, eta):
    """Unprojected gradient step (the master update for least squares)."""
    return theta - eta * grad


def iht_step(theta, grad, eta, u: int):
    """IHT step: gradient step followed by hard thresholding ``H_u``."""
    t = theta - eta * grad
    k = t.shape[0]
    if u == 0:
        return jnp.zeros_like(t)
    if u >= k:
        return t
    mags = jnp.abs(t)
    # Threshold at the u-th largest magnitude.
    thresh = jnp.sort(mags)[k - u]
    return jnp.where(mags >= thresh, t, 0.0)
