"""L1 — Pallas kernel for the coded-shard mat-vec (the worker hot-spot).

Every worker task in the moment-encoded runtime reduces to a dense
mat-vec over an encoded shard: ``out = rows @ theta`` with ``rows`` of
shape ``(R, K)``. On TPU the kernel tiles the shard through VMEM:

* grid = ``(R/TILE_R, K/TILE_K)``; each step stages a ``(TILE_R,
  TILE_K)`` block of ``rows`` and a ``(TILE_K,)`` slice of ``theta`` into
  VMEM (the ``BlockSpec``s below express the HBM->VMEM schedule a CUDA
  implementation would write with threadblocks);
* the inner product accumulates into a ``(TILE_R,)`` f32 accumulator in
  the output ref; the K-axis is the *minor* (fastest-varying) grid axis,
  so each output tile is initialized at ``j == 0`` and accumulated in
  place across the K sweep — the standard Pallas reduction pattern;
* ``TILE_K = 512`` keeps the staged block at 64*512*4 B = 128 KiB, far
  below the ~16 MiB VMEM budget even with double buffering, and the
  ``jnp.dot`` maps onto the MXU.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers the kernel to plain HLO
that both the pytest oracle checks and the Rust runtime can run. VMEM /
MXU utilization estimates for a real TPU are derived from the BlockSpecs
in DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (see module docstring for the VMEM accounting).
TILE_R = 64
TILE_K = 512


def _matvec_kernel(rows_ref, theta_ref, out_ref):
    """One grid step: accumulate rows_block @ theta_block into out."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    block = rows_ref[...]  # (TILE_R, TILE_K)
    theta = theta_ref[...]  # (TILE_K,)
    # MXU-friendly contraction with explicit f32 accumulation.
    out_ref[...] += jnp.dot(
        block, theta, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_k", "interpret"))
def coded_matvec(rows, theta, *, tile_r=TILE_R, tile_k=TILE_K, interpret=True):
    """Tiled ``rows @ theta`` via a Pallas kernel.

    Accepts arbitrary ``(R, K)`` shapes; pads statically to tile
    multiples (zero rows/columns contribute nothing) and slices the
    result back.
    """
    r, k = rows.shape
    if theta.shape != (k,):
        raise ValueError(f"theta shape {theta.shape} != ({k},)")
    tr = min(tile_r, _ceil_to(r, 8))
    tk = min(tile_k, _ceil_to(k, 128))
    rp = _ceil_to(r, tr)
    kp = _ceil_to(k, tk)
    rows_p = jnp.pad(rows, ((0, rp - r), (0, kp - k)))
    theta_p = jnp.pad(theta, (0, kp - k))
    grid = (rp // tr, kp // tk)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, tk), lambda i, j: (i, j)),
            pl.BlockSpec((tk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tr,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rp,), rows.dtype),
        interpret=interpret,
    )(rows_p, theta_p)
    return out[:r]


def vmem_bytes(tile_r: int = TILE_R, tile_k: int = TILE_K, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint of one grid step (double-buffered).

    rows block + theta slice + out accumulator, x2 for double buffering —
    the number DESIGN.md's roofline estimate uses.
    """
    single = (tile_r * tile_k + tile_k + tile_r) * dtype_bytes
    return 2 * single
