"""AOT lowering: JAX/Pallas (L1+L2) -> HLO text artifacts for the Rust
runtime (L3).

HLO **text** — not ``lowered.compile().serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids that the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact naming (parsed by ``rust/src/runtime/artifact.rs``):

    shard_matvec_{R}x{K}.hlo.txt   (rows f32[R,K], theta f32[K]) -> (f32[R],)
    local_grad_{R}x{K}.hlo.txt     (x f32[R,K], y f32[R], theta f32[K]) -> (f32[K],)

The shape set covers the paper's experiment grid (Figs. 1-3 worker shard
shapes) plus generic power-of-two fallbacks the Rust registry pads into.

Usage: python -m compile.aot [--out-dir ../artifacts] [--force]
"""

from __future__ import annotations

import argparse
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# (alpha = ceil(k/K_code), k) shard shapes for the moment schemes:
# fig1 k in {200,400,800,1000} with the (40,20) code, fig3 k=2000;
# power-of-two fallbacks for everything else.
SHARD_MATVEC_SHAPES = [
    (10, 200),
    (20, 400),
    (40, 800),
    (50, 1000),
    (100, 2000),
    (64, 1024),
    (128, 2048),
]

# (rows-per-worker, k) for the data-parallel schemes: uncoded/replication
# (m=2048 over 40 workers -> 52), KSDY17 (4096 encoded rows over 40
# workers -> 103), plus fallbacks.
LOCAL_GRAD_SHAPES = [
    (52, 200),
    (52, 400),
    (52, 800),
    (52, 1000),
    (103, 200),
    (103, 400),
    (103, 800),
    (103, 1000),
    (64, 2048),
    (128, 2048),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_shard_matvec(r: int, k: int) -> str:
    spec_rows = jax.ShapeDtypeStruct((r, k), jax.numpy.float32)
    spec_theta = jax.ShapeDtypeStruct((k,), jax.numpy.float32)
    return to_hlo_text(jax.jit(model.shard_matvec).lower(spec_rows, spec_theta))


def lower_local_grad(r: int, k: int) -> str:
    spec_x = jax.ShapeDtypeStruct((r, k), jax.numpy.float32)
    spec_y = jax.ShapeDtypeStruct((r,), jax.numpy.float32)
    spec_theta = jax.ShapeDtypeStruct((k,), jax.numpy.float32)
    return to_hlo_text(jax.jit(model.local_grad).lower(spec_x, spec_y, spec_theta))


def build(out_dir: pathlib.Path, force: bool = False) -> list[pathlib.Path]:
    """Write all artifacts; skip files that already exist unless forced."""
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    jobs = [("shard_matvec", lower_shard_matvec, SHARD_MATVEC_SHAPES), (
        "local_grad",
        lower_local_grad,
        LOCAL_GRAD_SHAPES,
    )]
    for name, lower, shapes in jobs:
        for r, k in shapes:
            path = out_dir / f"{name}_{r}x{k}.hlo.txt"
            if path.exists() and not force:
                continue
            text = lower(r, k)
            path.write_text(text)
            written.append(path)
            print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--force", action="store_true", help="rebuild existing artifacts")
    # Back-compat: Makefile may pass --out <file> to request the default set.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.out_dir)
    written = build(out_dir, force=args.force)
    print(f"{len(written)} artifacts written to {out_dir}", file=sys.stderr)
    # Stamp file so make can track freshness.
    (out_dir / ".stamp").write_text("ok\n")


if __name__ == "__main__":
    main()
