"""L2 correctness: the model graphs vs the oracle, including the exact
artifact shapes the Rust runtime will execute."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@pytest.mark.parametrize("r,k", [(10, 200), (50, 1000), (13, 77)])
def test_shard_matvec(r, k):
    rows = rand((r, k), seed=1)
    theta = rand((k,), seed=2)
    (got,) = model.shard_matvec(rows, theta)
    want = ref.matvec(rows, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("r,k", [(52, 200), (103, 400), (9, 33)])
def test_local_grad(r, k):
    x = rand((r, k), seed=3)
    y = rand((r,), seed=4)
    theta = rand((k,), seed=5)
    (got,) = model.local_grad(x, y, theta)
    want = ref.local_grad(x, y, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-3)


@hypothesis.given(
    r=st.integers(min_value=1, max_value=120),
    k=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_local_grad_hypothesis(r, k, seed):
    x = rand((r, k), seed=seed)
    y = rand((r,), seed=seed + 1)
    theta = rand((k,), seed=seed + 2)
    (got,) = model.local_grad(x, y, theta)
    want = ref.local_grad(x, y, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-3)


def test_pgd_step():
    theta = rand((30,), seed=6)
    grad = rand((30,), seed=7)
    (got,) = model.pgd_step(theta, grad, 0.1)
    want = ref.pgd_step(theta, grad, 0.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("u", [0, 1, 5, 29, 30, 50])
def test_iht_step_sparsity(u):
    theta = rand((30,), seed=8)
    grad = rand((30,), seed=9)
    (got,) = model.iht_step(theta, grad, 0.1, u)
    nnz = int(np.count_nonzero(np.asarray(got)))
    assert nnz <= max(u, 0) or u >= 30
    # Matches the oracle.
    want = ref.iht_step(theta, grad, 0.1, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_iht_keeps_largest():
    theta = jnp.zeros((5,), jnp.float32)
    grad = jnp.asarray([-5.0, 1.0, -3.0, 0.5, 2.0], jnp.float32)
    (got,) = model.iht_step(theta, grad, 1.0, 2)
    # step = [5, -1, 3, -0.5, -2]; top-2 magnitudes at indices 0, 2.
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray([5.0, 0.0, 3.0, 0.0, 0.0], np.float32)
    )
