"""AOT pipeline: artifacts are valid HLO text with the right interface,
and incremental rebuild skips existing files."""

import pathlib

import jax
import numpy as np
import pytest

from compile import aot


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    """Lower one small shape of each kernel into a temp dir."""
    out = tmp_path_factory.mktemp("artifacts")
    (out / "shard_matvec_8x16.hlo.txt").write_text(aot.lower_shard_matvec(8, 16))
    (out / "local_grad_8x16.hlo.txt").write_text(aot.lower_local_grad(8, 16))
    return out


def test_hlo_text_structure(tiny_artifacts):
    for p in tiny_artifacts.iterdir():
        text = p.read_text()
        assert "ENTRY" in text, f"{p.name}: not HLO text"
        assert "f32[" in text


def test_shard_matvec_interface(tiny_artifacts):
    text = (tiny_artifacts / "shard_matvec_8x16.hlo.txt").read_text()
    assert "f32[8,16]" in text, "rows parameter shape"
    assert "f32[16]" in text, "theta parameter shape"
    assert "(f32[8])" in text or "f32[8]" in text, "result shape"


def test_local_grad_interface(tiny_artifacts):
    text = (tiny_artifacts / "local_grad_8x16.hlo.txt").read_text()
    assert "f32[8,16]" in text
    assert "f32[8]" in text  # y
    assert "f32[16]" in text  # theta / result


def test_lowered_computation_executes(tiny_artifacts):
    """Compile the XlaComputation we serialize (pre-text) on jax's own CPU
    client and compare numbers; the HLO-*text* round-trip itself is
    covered end-to-end by the Rust integration test
    (rust/tests/integration_pjrt.rs)."""
    from jax._src.lib import xla_client as xc
    from compile.kernels import ref
    from compile import model

    rng = np.random.default_rng(0)
    rows = rng.standard_normal((8, 16)).astype(np.float32)
    theta = rng.standard_normal(16).astype(np.float32)
    lowered = jax.jit(model.shard_matvec).lower(
        jax.ShapeDtypeStruct((8, 16), np.float32),
        jax.ShapeDtypeStruct((16,), np.float32),
    )
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    assert "ENTRY" in comp.as_hlo_text()
    # Execute the lowered module through jax's runtime to validate numbers.
    exe = jax.jit(model.shard_matvec).lower(
        jax.ShapeDtypeStruct((8, 16), np.float32),
        jax.ShapeDtypeStruct((16,), np.float32),
    ).compile()
    (got,) = exe(rows, theta)
    want = np.asarray(ref.matvec(rows, theta))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-4)


def test_build_writes_and_skips(tmp_path):
    shapes_backup = (aot.SHARD_MATVEC_SHAPES, aot.LOCAL_GRAD_SHAPES)
    aot.SHARD_MATVEC_SHAPES = [(4, 8)]
    aot.LOCAL_GRAD_SHAPES = [(4, 8)]
    try:
        written = aot.build(pathlib.Path(tmp_path))
        assert len(written) == 2
        # Second run: everything exists, nothing rewritten.
        again = aot.build(pathlib.Path(tmp_path))
        assert again == []
        # Forced: rebuilt.
        forced = aot.build(pathlib.Path(tmp_path), force=True)
        assert len(forced) == 2
    finally:
        aot.SHARD_MATVEC_SHAPES, aot.LOCAL_GRAD_SHAPES = shapes_backup
