"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; explicit cases pin the paper's
experiment shapes. This is the core correctness signal for the compute
hot path — if these pass, every worker task the Rust runtime executes
through the AOT artifacts computes the right numbers.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.coded_matvec import TILE_K, TILE_R, coded_matvec, vmem_bytes


def rand(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@pytest.mark.parametrize(
    "r,k",
    [
        (1, 1),
        (1, 513),
        (7, 64),
        (10, 200),   # fig1 k=200 shard
        (50, 1000),  # fig1 k=1000 shard
        (64, 512),   # exact tile
        (65, 513),   # just over tile
        (100, 2000), # fig3 shard
    ],
)
def test_matvec_matches_ref(r, k):
    rows = rand((r, k), seed=r * 1000 + k)
    theta = rand((k,), seed=r + k)
    got = coded_matvec(rows, theta)
    want = ref.matvec(rows, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


@hypothesis.given(
    r=st.integers(min_value=1, max_value=160),
    k=st.integers(min_value=1, max_value=700),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_matvec_matches_ref_hypothesis(r, k, seed):
    rows = rand((r, k), seed=seed)
    theta = rand((k,), seed=seed + 1)
    got = coded_matvec(rows, theta)
    want = ref.matvec(rows, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


@hypothesis.given(
    tile_r=st.sampled_from([8, 16, 64]),
    tile_k=st.sampled_from([128, 256, 512]),
)
@hypothesis.settings(max_examples=9, deadline=None)
def test_matvec_tile_invariance(tile_r, tile_k):
    """The result must not depend on the tiling (double-buffer schedule)."""
    rows = rand((70, 300), seed=3)
    theta = rand((300,), seed=4)
    got = coded_matvec(rows, theta, tile_r=tile_r, tile_k=tile_k)
    want = ref.matvec(rows, theta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


def test_matvec_float64_supported():
    # jax defaults to f32; with x64 disabled f64 inputs downcast, which is
    # fine — the artifact path is f32. Just check no crash and closeness.
    rows = rand((9, 33), seed=5, dtype=jnp.float32)
    theta = rand((33,), seed=6, dtype=jnp.float32)
    got = coded_matvec(rows, theta)
    assert got.dtype == jnp.float32
    assert got.shape == (9,)


def test_zero_matrix_gives_zero():
    rows = jnp.zeros((17, 45), jnp.float32)
    theta = rand((45,), seed=7)
    got = coded_matvec(rows, theta)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(17, np.float32))


def test_shape_mismatch_raises():
    rows = jnp.zeros((4, 5), jnp.float32)
    theta = jnp.zeros((6,), jnp.float32)
    with pytest.raises(ValueError):
        coded_matvec(rows, theta)


def test_padding_is_exact():
    """Zero-padding must not perturb the result beyond summation-order
    noise: embedding the same data in a larger zero block changes only
    the tile split (and hence f32 accumulation order), never the math."""
    rows = rand((10, 100), seed=8)
    theta = rand((100,), seed=9)
    small = coded_matvec(rows, theta)
    rows_big = jnp.pad(rows, ((0, 54), (0, 412)))
    theta_big = jnp.pad(theta, (0, 412))
    big = coded_matvec(rows_big, theta_big)[:10]
    np.testing.assert_allclose(np.asarray(small), np.asarray(big), rtol=1e-6, atol=1e-5)


def test_vmem_budget():
    """The DESIGN.md hardware-adaptation claim: the default tile's
    double-buffered VMEM footprint stays far below a TPU core's ~16 MiB."""
    assert vmem_bytes(TILE_R, TILE_K) < 1 << 20  # < 1 MiB


def test_kernel_is_jittable_and_stable():
    rows = rand((12, 70), seed=10)
    theta = rand((70,), seed=11)
    a = coded_matvec(rows, theta)
    b = coded_matvec(rows, theta)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_linearity():
    """Kernel must be linear in theta (codeword property relies on it)."""
    rows = rand((20, 90), seed=12)
    t1 = rand((90,), seed=13)
    t2 = rand((90,), seed=14)
    lhs = coded_matvec(rows, t1 + 2.0 * t2)
    rhs = coded_matvec(rows, t1) + 2.0 * coded_matvec(rows, t2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-3)
